//! Statistics helpers: summary stats, percentiles, and the ordinary
//! least-squares fit behind the paper's Eq. 10 online optimizer.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation (`q` in [0, 100]); 0.0 for empty.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Summary of a sample (used by the bench harness and reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50: percentile(xs, 50.0),
            p99: percentile(xs, 99.0),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

/// Ridge-regularized OLS for `y = b0 + b1*x1 + b2*x2` — the exact model
/// shape of the paper's Eq. 10. Ridge `lambda` keeps the 3x3 normal system
/// solvable when the history is (nearly) collinear, which happens in the
/// first micro-batches when the inflection point has not moved yet.
///
/// Returns `[b0, b1, b2]`, or `None` if fewer than 3 samples.
pub fn ols2(x1: &[f64], x2: &[f64], y: &[f64], lambda: f64) -> Option<[f64; 3]> {
    let n = y.len();
    if n < 3 || x1.len() != n || x2.len() != n {
        return None;
    }
    // Normal equations: (X^T X + λI) b = X^T y with X = [1, x1, x2].
    let nf = n as f64;
    let s1: f64 = x1.iter().sum();
    let s2: f64 = x2.iter().sum();
    let s11: f64 = x1.iter().map(|a| a * a).sum();
    let s22: f64 = x2.iter().map(|a| a * a).sum();
    let s12: f64 = x1.iter().zip(x2).map(|(a, b)| a * b).sum();
    let sy: f64 = y.iter().sum();
    let s1y: f64 = x1.iter().zip(y).map(|(a, b)| a * b).sum();
    let s2y: f64 = x2.iter().zip(y).map(|(a, b)| a * b).sum();

    let mut a = [
        [nf + lambda, s1, s2],
        [s1, s11 + lambda, s12],
        [s2, s12, s22 + lambda],
    ];
    let mut b = [sy, s1y, s2y];
    solve3(&mut a, &mut b)
}

/// Gaussian elimination with partial pivoting for a 3x3 system.
fn solve3(a: &mut [[f64; 3]; 3], b: &mut [f64; 3]) -> Option<[f64; 3]> {
    for col in 0..3 {
        // Pivot.
        let mut piv = col;
        for row in (col + 1)..3 {
            if a[row][col].abs() > a[piv][col].abs() {
                piv = row;
            }
        }
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        // Eliminate below.
        for row in (col + 1)..3 {
            let f = a[row][col] / a[col][col];
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    // Back substitution.
    let mut x = [0.0; 3];
    for col in (0..3).rev() {
        let mut acc = b[col];
        for k in (col + 1)..3 {
            acc -= a[col][k] * x[k];
        }
        x[col] = acc / a[col][col];
    }
    Some(x)
}

/// Exponential moving average helper.
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn summary_fields_consistent() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn ols_recovers_exact_plane() {
        // y = 2 + 3*x1 - 0.5*x2, noiseless.
        let x1: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let x2: Vec<f64> = (0..20).map(|i| ((i * 7) % 13) as f64).collect();
        let y: Vec<f64> =
            x1.iter().zip(&x2).map(|(a, b)| 2.0 + 3.0 * a - 0.5 * b).collect();
        let [b0, b1, b2] = ols2(&x1, &x2, &y, 0.0).unwrap();
        assert!((b0 - 2.0).abs() < 1e-8, "{b0}");
        assert!((b1 - 3.0).abs() < 1e-9, "{b1}");
        assert!((b2 + 0.5).abs() < 1e-9, "{b2}");
    }

    #[test]
    fn ols_degenerate_without_ridge_none() {
        // x2 = 2*x1 exactly: singular normal matrix.
        let x1: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let x2: Vec<f64> = x1.iter().map(|a| 2.0 * a).collect();
        let y: Vec<f64> = x1.iter().map(|a| 1.0 + a).collect();
        assert!(ols2(&x1, &x2, &y, 0.0).is_none());
        // Ridge makes it solvable.
        assert!(ols2(&x1, &x2, &y, 1e-3).is_some());
    }

    #[test]
    fn ols_needs_three_points(){
        assert!(ols2(&[1.0], &[1.0], &[1.0], 0.0).is_none());
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        e.update(0.0);
        for _ in 0..30 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }
}
