//! Minimal command-line parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value]...`. Typed accessors
//! with defaults; unknown-argument detection via [`Args::finish`].

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    /// First positional token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("bare `--` not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.opts.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option.
    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).cloned()
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    /// Typed numeric option.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        self.mark(key);
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: bad number `{v}`"))),
        }
    }

    /// Typed integer option.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        self.mark(key);
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: bad integer `{v}`"))),
        }
    }

    /// u64 option.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        self.mark(key);
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: bad integer `{v}`"))),
        }
    }

    /// Duration option given in (fractional) seconds, e.g. `--trigger 10`.
    pub fn secs_or(&self, key: &str, default: Duration) -> Result<Duration> {
        Ok(Duration::from_secs_f64(
            self.f64_or(key, default.as_secs_f64())?,
        ))
    }

    /// Boolean flag (`--verbose`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any option/flag that no accessor asked about (catches
    /// typos like `--triger`).
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                return Err(Error::Config(format!("unknown argument --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["run", "--workload", "lr1s", "--seed", "7"]);
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.str_or("workload", ""), "lr1s");
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse(&["bench", "--fig=6", "--verbose"]);
        assert_eq!(a.str_or("fig", ""), "6");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn durations_in_seconds() {
        let a = parse(&["run", "--trigger", "2.5"]);
        assert_eq!(
            a.secs_or("trigger", Duration::ZERO).unwrap(),
            Duration::from_millis(2500)
        );
    }

    #[test]
    fn finish_rejects_unknown() {
        let a = parse(&["run", "--bogus", "1"]);
        assert!(a.finish().is_err());
        let b = parse(&["run", "--seed", "1"]);
        b.u64_or("seed", 0).unwrap();
        b.finish().unwrap();
    }

    #[test]
    fn bad_number_is_config_error() {
        let a = parse(&["run", "--seed", "xyz"]);
        assert!(a.u64_or("seed", 0).is_err());
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = parse(&["run", "--verbose", "--seed", "3"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.u64_or("seed", 0).unwrap(), 3);
    }
}
