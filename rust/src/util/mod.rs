//! In-repo replacements for crates unavailable in the offline build
//! environment (see DESIGN.md "Environment constraints"): a seeded PRNG,
//! statistics helpers, a JSON reader/writer, a mini CLI parser, a bench
//! harness and a property-testing kit.

pub mod bench;
pub mod cli;
pub mod exec;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
