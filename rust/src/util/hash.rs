//! Fast non-cryptographic hashing for the engine's hot hash tables.
//!
//! std's default SipHash is DoS-resistant but ~4x slower than needed for
//! the join/aggregate inner loops over trusted, engine-generated keys.
//! This is the FxHash multiply-xor scheme (rustc's own table hasher).

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style hasher: word-at-a-time multiply-rotate-xor.
#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

impl FxHasher {
    #[inline]
    fn mix(&mut self, word: u64) {
        self.state = (self.state.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.mix(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rest.len()].copy_from_slice(rest);
            self.mix(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.mix(n);
    }

    #[inline]
    fn write_i64(&mut self, n: i64) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.mix(n as u64);
    }
}

/// Drop-in `HashMap` state for hot tables.
pub type FxBuild = BuildHasherDefault<FxHasher>;

/// `HashMap` with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuild>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_one<T: Hash>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic() {
        assert_eq!(hash_one(&42i64), hash_one(&42i64));
        assert_ne!(hash_one(&42i64), hash_one(&43i64));
    }

    #[test]
    fn map_works_like_std() {
        let mut m: FxHashMap<i64, usize> = FxHashMap::default();
        for i in 0..1000i64 {
            m.insert(i * 7, i as usize);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&(7 * 999)], 999);
        assert!(!m.contains_key(&1));
    }

    #[test]
    fn distribution_spreads_sequential_keys() {
        // Sequential keys: all hashes distinct, and the low bits (the
        // ones hashbrown uses for bucket selection) well spread.
        let full: std::collections::BTreeSet<u64> =
            (0..10_000i64).map(|i| hash_one(&i)).collect();
        assert_eq!(full.len(), 10_000);
        let low: std::collections::BTreeSet<u64> =
            (0..10_000i64).map(|i| hash_one(&i) & 0xfff).collect();
        assert!(low.len() > 3000, "only {} distinct low-bit buckets", low.len());
    }

    #[test]
    fn composite_keys_hash() {
        let a = hash_one(&vec![1i64, 2, 3]);
        let b = hash_one(&vec![1i64, 2, 4]);
        assert_ne!(a, b);
    }
}
