//! Micro-bench harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is a `harness = false` binary that uses
//! [`Bencher`] for timing loops and the table printers for paper-shaped
//! output. Methodology: warm-up iterations, then timed batches until both
//! a minimum iteration count and a minimum elapsed budget are reached;
//! reports mean / p50 / p99 over per-iteration times.

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// Configuration for one measurement.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub min_time: Duration,
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000,
        }
    }
}

/// One benchmark result row.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_secs_f64(self.summary.mean.max(0.0))
    }
}

/// Timing loop driver.
pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(BenchConfig::default())
    }
}

impl Bencher {
    pub fn new(cfg: BenchConfig) -> Self {
        Bencher { cfg, results: Vec::new() }
    }

    /// Fast preset for heavyweight end-to-end benches (one sim run per
    /// iteration).
    pub fn endtoend() -> Self {
        Bencher::new(BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            min_time: Duration::from_millis(100),
            max_iters: 10,
        })
    }

    /// Measure `f`, preventing dead-code elimination via the returned
    /// value's observation.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while (times.len() < self.cfg.min_iters || start.elapsed() < self.cfg.min_time)
            && times.len() < self.cfg.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            summary: Summary::of(&times),
        });
        self.results.last().unwrap()
    }

    /// All measurements so far (machine-readable export).
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Mean seconds of the measurement named `name` (0.0 if absent).
    pub fn mean_of(&self, name: &str) -> f64 {
        self.results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.summary.mean)
            .unwrap_or(0.0)
    }

    /// Print a criterion-style summary of every measurement.
    pub fn report(&self) {
        println!("\n{:-<78}", "");
        println!(
            "{:<42} {:>10} {:>10} {:>10}",
            "benchmark", "mean", "p50", "p99"
        );
        println!("{:-<78}", "");
        for r in &self.results {
            println!(
                "{:<42} {:>10} {:>10} {:>10}",
                r.name,
                fmt_secs(r.summary.mean),
                fmt_secs(r.summary.p50),
                fmt_secs(r.summary.p99),
            );
        }
        println!("{:-<78}", "");
    }
}

/// Human duration formatting (ns/µs/ms/s auto-scale).
pub fn fmt_secs(s: f64) -> String {
    if s < 0.0 {
        return "-".into();
    }
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Paper-style table printer: header + aligned rows.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut out = String::new();
        for (i, c) in cells.iter().enumerate() {
            out.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        println!("{}", out.trim_end());
    };
    line(header.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iterations() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            min_time: Duration::ZERO,
            max_iters: 100,
        });
        let mut count = 0usize;
        b.bench("noop", || {
            count += 1;
            count
        });
        assert!(count >= 5 + 1); // warmup + timed
        assert!(b.results[0].summary.n >= 5);
    }

    #[test]
    fn max_iters_caps_loop() {
        let mut b = Bencher::new(BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            min_time: Duration::from_secs(5),
            max_iters: 7,
        });
        b.bench("noop", || 1);
        assert_eq!(b.results[0].summary.n, 7);
    }

    #[test]
    fn fmt_secs_scales() {
        assert!(fmt_secs(5e-10).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
