//! Property-testing kit (proptest is unavailable offline).
//!
//! Seeded generator-driven sweeps with failing-case shrinking for the
//! coordinator invariants (routing, batching, state). Usage:
//!
//! ```no_run
//! # // no_run: doctest binaries miss the xla rpath in this offline image
//! use lmstream::util::prop::{prop_assert, Runner};
//! let mut r = Runner::new(0xfeed, 200);
//! r.run("sum non-negative", |g| {
//!     let xs = g.vec_f64(0.0, 10.0, 1..50);
//!     let s: f64 = xs.iter().sum();
//!     prop_assert(s >= 0.0, format!("sum {s}"))
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Assert helper producing a property failure message.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Case-input generator with size tracking (shrinking re-runs the property
/// at smaller `size` budgets).
pub struct Gen {
    rng: Rng,
    /// Size budget in [0.0, 1.0]; generators scale ranges by it.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Gen {
        Gen { rng: Rng::new(seed), size }
    }

    /// Standalone full-size generator for tests that want seeded random
    /// inputs outside a [`Runner`] sweep.
    pub fn for_tests(seed: u64) -> Gen {
        Gen::new(seed, 1.0)
    }

    pub fn u64(&mut self, max: u64) -> u64 {
        let scaled = ((max as f64) * self.size).max(1.0) as u64;
        self.rng.below(scaled.min(max).max(1))
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        if r.is_empty() {
            return r.start;
        }
        let span = (r.end - r.start) as u64;
        let scaled = ((span as f64 * self.size).ceil() as u64).clamp(1, span);
        r.start + self.rng.below(scaled) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let hi_scaled = lo + (hi - lo) * self.size.max(0.05);
        self.rng.uniform(lo, hi_scaled.max(lo + f64::EPSILON))
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn vec_f64(&mut self, lo: f64, hi: f64, len: Range<usize>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, max: usize, len: Range<usize>) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.u64(max as u64) as usize).collect()
    }

    /// Access the raw RNG for domain-specific generation.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Property sweep driver.
pub struct Runner {
    seed: u64,
    cases: usize,
}

impl Runner {
    pub fn new(seed: u64, cases: usize) -> Runner {
        Runner { seed, cases }
    }

    /// Run `prop` over `cases` seeded inputs; on failure, retry at smaller
    /// size budgets (simple shrinking) and panic with the smallest
    /// reproducer's seed + message.
    pub fn run<F: FnMut(&mut Gen) -> CaseResult>(&mut self, name: &str, mut prop: F) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9e3779b97f4a7c15);
            if let Err(msg) = prop(&mut Gen::new(case_seed, 1.0)) {
                // Shrink: re-run the same seed at smaller sizes, keep the
                // smallest size that still fails.
                let mut best = (1.0f64, msg);
                for &size in &[0.5, 0.25, 0.1, 0.05, 0.02] {
                    if let Err(m) = prop(&mut Gen::new(case_seed, size)) {
                        best = (size, m);
                    } else {
                        break;
                    }
                }
                panic!(
                    "property `{name}` failed (case {case}, seed {case_seed:#x}, \
                     shrunk size {}): {}",
                    best.0, best.1
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        let mut r = Runner::new(1, 50);
        r.run("abs non-negative", |g| {
            let x = g.f64_in(-100.0, 100.0);
            prop_assert(x.abs() >= 0.0, "impossible")
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_seed() {
        let mut r = Runner::new(2, 10);
        r.run("always fails", |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert(x < 0.0, format!("x = {x}"))
        });
    }

    #[test]
    fn generators_respect_ranges() {
        let mut g = Gen::new(3, 1.0);
        for _ in 0..1000 {
            let v = g.usize_in(5..10);
            assert!((5..10).contains(&v));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    fn vec_lengths_in_range() {
        let mut g = Gen::new(4, 1.0);
        for _ in 0..100 {
            let v = g.vec_f64(0.0, 1.0, 2..7);
            assert!((2..7).contains(&v.len()));
        }
    }

    #[test]
    fn same_seed_same_cases() {
        let mut g1 = Gen::new(99, 1.0);
        let mut g2 = Gen::new(99, 1.0);
        assert_eq!(g1.u64(1000), g2.u64(1000));
        assert_eq!(g1.f64_in(0.0, 1.0), g2.f64_in(0.0, 1.0));
    }
}
