//! Threading helpers: scoped parallel map over partitions and a
//! single-consumer background worker (the tokio replacement).
//!
//! The coordinator's partition fan-out uses [`par_map`]; the paper's
//! asynchronous optimizer (§III-E) runs on a [`Worker`].

use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;

/// Parallel map over `items` with at most `threads` OS threads, preserving
/// input order. Falls back to sequential for 1 thread or 1 item.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    if threads <= 1 || n == 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let results: Mutex<Vec<Option<R>>> =
        Mutex::new((0..n).map(|_| None).collect());
    thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(|| loop {
                let next = work.lock().unwrap().next();
                match next {
                    None => break,
                    Some((i, item)) => {
                        let r = f(i, item);
                        results.lock().unwrap()[i] = Some(r);
                    }
                }
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("par_map slot unfilled"))
        .collect()
}

/// A background worker consuming jobs of type `J` and publishing the most
/// recent result of type `R`. Job submission never blocks; result pickup
/// is non-blocking (`latest`) or bounded-blocking (`wait_latest`).
pub struct Worker<J: Send + 'static, R: Send + 'static> {
    tx: Sender<J>,
    latest: Arc<Mutex<Option<R>>>,
    done_rx: Receiver<()>,
    handle: Option<thread::JoinHandle<()>>,
}

impl<J: Send + 'static, R: Send + 'static> Worker<J, R> {
    /// Spawn with a job handler. The handler's return value replaces the
    /// published `latest` result.
    pub fn spawn<F>(name: &str, mut handler: F) -> Worker<J, R>
    where
        F: FnMut(J) -> R + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<J>();
        let (done_tx, done_rx) = mpsc::channel::<()>();
        let latest: Arc<Mutex<Option<R>>> = Arc::new(Mutex::new(None));
        let latest2 = Arc::clone(&latest);
        let handle = thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    let r = handler(job);
                    *latest2.lock().unwrap() = Some(r);
                    let _ = done_tx.send(());
                }
            })
            .expect("spawn worker");
        Worker { tx, latest, done_rx, handle: Some(handle) }
    }

    /// Enqueue a job (non-blocking).
    pub fn submit(&self, job: J) {
        let _ = self.tx.send(job);
    }

    /// Take the most recent published result, if any.
    pub fn latest(&self) -> Option<R> {
        self.latest.lock().unwrap().take()
    }

    /// Wait up to `timeout` for at least one completion signal, then take
    /// the latest result. Returns (result, waited), where `waited` is how
    /// long the caller actually blocked — this is the paper's
    /// "optimization blocking" time (Table IV).
    pub fn wait_latest(&self, timeout: std::time::Duration) -> (Option<R>, std::time::Duration) {
        let t0 = std::time::Instant::now();
        if self.latest.lock().unwrap().is_some() {
            return (self.latest(), std::time::Duration::ZERO);
        }
        // Drain stale signals, then block for a fresh one.
        loop {
            match self.done_rx.try_recv() {
                Ok(()) => {
                    if self.latest.lock().unwrap().is_some() {
                        return (self.latest(), t0.elapsed());
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => return (None, t0.elapsed()),
            }
        }
        match self.done_rx.recv_timeout(timeout) {
            Ok(()) => (self.latest(), t0.elapsed()),
            Err(_) => (None, t0.elapsed()),
        }
    }
}

impl<J: Send + 'static, R: Send + 'static> Drop for Worker<J, R> {
    fn drop(&mut self) {
        // Closing the channel stops the worker loop.
        let (tx, _) = mpsc::channel();
        drop(std::mem::replace(&mut self.tx, tx));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        let ys = par_map(xs, 8, |_, x| x * 2);
        assert_eq!(ys, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_sequential_fallback() {
        let ys = par_map(vec![1, 2, 3], 1, |i, x| i + x);
        assert_eq!(ys, vec![1, 3, 5]);
    }

    #[test]
    fn par_map_empty() {
        let ys: Vec<i32> = par_map(Vec::<i32>::new(), 4, |_, x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn worker_publishes_latest() {
        let w: Worker<i32, i32> = Worker::spawn("test", |j| j * 10);
        w.submit(1);
        w.submit(2);
        let (r, _) = w.wait_latest(Duration::from_secs(1));
        assert!(matches!(r, Some(10) | Some(20)));
    }

    #[test]
    fn worker_latest_is_consumed_once() {
        let w: Worker<i32, i32> = Worker::spawn("test", |j| j);
        w.submit(5);
        let (r, _) = w.wait_latest(Duration::from_secs(1));
        assert_eq!(r, Some(5));
        assert_eq!(w.latest(), None);
    }

    #[test]
    fn worker_timeout_returns_none() {
        let w: Worker<i32, i32> = Worker::spawn("test", |j| {
            std::thread::sleep(Duration::from_millis(200));
            j
        });
        w.submit(1);
        let (r, waited) = w.wait_latest(Duration::from_millis(10));
        assert!(r.is_none());
        assert!(waited >= Duration::from_millis(10));
    }
}
