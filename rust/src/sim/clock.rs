//! Virtual / wall clock abstraction.
//!
//! All coordinator code reads time through [`Clock`], so a run is either
//! driven by the discrete-event [`SimClock`] (paper-scale experiments,
//! deterministic) or by [`WallClock`] (real execution through PJRT).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Nanoseconds since the start of the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

impl Time {
    pub const ZERO: Time = Time(0);

    pub fn from_secs_f64(s: f64) -> Time {
        Time((s.max(0.0) * 1e9) as u64)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn saturating_sub(self, other: Time) -> Duration {
        Duration::from_nanos(self.0.saturating_sub(other.0))
    }

    pub fn add(self, d: Duration) -> Time {
        Time(self.0 + d.as_nanos() as u64)
    }
}

/// Time source + time sink. `advance` models elapsed work: the sim clock
/// jumps, the wall clock actually sleeps only when asked to idle (never
/// for compute, whose duration is real there).
pub trait Clock: Send + Sync {
    /// Current time since run start.
    fn now(&self) -> Time;

    /// Account `d` of simulated work ending now (sim: jump; wall: no-op —
    /// the work itself took the time).
    fn advance(&self, d: Duration);

    /// Idle until `deadline` (poll sleeps).
    fn sleep_until(&self, deadline: Time);

    /// True when this clock is virtual.
    fn is_simulated(&self) -> bool;
}

/// Deterministic virtual clock.
#[derive(Clone, Default)]
pub struct SimClock {
    now_ns: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }
}

impl Clock for SimClock {
    fn now(&self) -> Time {
        Time(self.now_ns.load(Ordering::SeqCst))
    }

    fn advance(&self, d: Duration) {
        self.now_ns.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }

    fn sleep_until(&self, deadline: Time) {
        // Monotone: never move backwards.
        let mut cur = self.now_ns.load(Ordering::SeqCst);
        while cur < deadline.0 {
            match self.now_ns.compare_exchange(
                cur,
                deadline.0,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    fn is_simulated(&self) -> bool {
        true
    }
}

/// Real time anchored at construction.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Time {
        Time(self.start.elapsed().as_nanos() as u64)
    }

    fn advance(&self, _d: Duration) {
        // Work on the wall clock takes real time already.
    }

    fn sleep_until(&self, deadline: Time) {
        let now = self.now();
        if deadline > now {
            std::thread::sleep(deadline.saturating_sub(now));
        }
    }

    fn is_simulated(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances_exactly() {
        let c = SimClock::new();
        assert_eq!(c.now(), Time::ZERO);
        c.advance(Duration::from_millis(1500));
        assert_eq!(c.now().as_secs_f64(), 1.5);
    }

    #[test]
    fn sim_sleep_until_jumps_forward_only() {
        let c = SimClock::new();
        c.sleep_until(Time::from_secs_f64(2.0));
        assert_eq!(c.now().as_secs_f64(), 2.0);
        c.sleep_until(Time::from_secs_f64(1.0)); // past deadline: no-op
        assert_eq!(c.now().as_secs_f64(), 2.0);
    }

    #[test]
    fn time_arithmetic() {
        let t = Time::from_secs_f64(1.0).add(Duration::from_millis(500));
        assert_eq!(t.as_secs_f64(), 1.5);
        assert_eq!(
            t.saturating_sub(Time::from_secs_f64(1.0)),
            Duration::from_millis(500)
        );
        assert_eq!(Time::from_secs_f64(1.0).saturating_sub(t), Duration::ZERO);
    }

    #[test]
    fn wall_clock_monotone() {
        let c = WallClock::new();
        let a = c.now();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now() > a);
    }

    #[test]
    fn sim_clock_shared_between_clones() {
        let c = SimClock::new();
        let c2 = c.clone();
        c.advance(Duration::from_secs(1));
        assert_eq!(c2.now().as_secs_f64(), 1.0);
    }
}
