//! Discrete-event simulation substrate: the virtual clock that lets the
//! bench harness replay the paper's 20-minute cluster runs in
//! milliseconds while executing the identical coordinator code.

pub mod clock;

pub use clock::{Clock, SimClock, Time, WallClock};
