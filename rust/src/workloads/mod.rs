//! The paper's evaluation workloads (Table III): Linear Road and Google
//! Cluster Monitoring, plus the synthetic select-project-join of
//! Figs. 2/5.

pub mod cluster_monitoring;
pub mod linear_road;
pub mod synthetic;

use crate::error::{Error, Result};
use crate::query::dag::Query;
use crate::source::stream::{Disorder, InputStream, RowGen};
use crate::source::traffic::Traffic;

/// A runnable workload: query + data generator + default traffic.
#[derive(Clone)]
pub struct Workload {
    pub name: &'static str,
    pub query: Query,
    pub traffic: Traffic,
    pub disorder: Option<Disorder>,
    make_gen: fn(u64) -> Box<dyn RowGen>,
}

impl Workload {
    pub fn new(
        name: &'static str,
        query: Query,
        traffic: Traffic,
        make_gen: fn(u64) -> Box<dyn RowGen>,
    ) -> Workload {
        Workload { name, query, traffic, disorder: None, make_gen }
    }

    /// Instantiate the input stream (seeded).
    pub fn make_stream(&self, seed: u64) -> InputStream {
        let stream = InputStream::new((self.make_gen)(seed), self.traffic, seed);
        match self.disorder {
            Some(d) => stream.with_disorder(d),
            None => stream,
        }
    }

    /// Override traffic (the §V experiments switch constant ↔ random).
    pub fn with_traffic(mut self, traffic: Traffic) -> Workload {
        self.traffic = traffic;
        self
    }

    /// Inject out-of-order arrival: datasets keep their event times but
    /// may be delayed on the wire (event-time experiments).
    pub fn with_disorder(mut self, disorder: Disorder) -> Workload {
        self.disorder = Some(disorder);
        self
    }
}

/// All Table III workload names (the set the paper figures iterate).
pub const ALL: &[&str] = &["lr1s", "lr1t", "lr2s", "cm1s", "cm1t", "cm2s"];

/// Every name [`by_name`] resolves: Table III plus the synthetic
/// select-project-join (`spj`) of Figs. 2/5. "Run everything" loops
/// should iterate this, not [`ALL`], or they silently skip `spj`.
pub const ALL_WITH_SYNTHETIC: &[&str] =
    &["lr1s", "lr1t", "lr2s", "cm1s", "cm1t", "cm2s", "spj"];

/// Look up a workload by its Table III notation (lowercase).
pub fn by_name(name: &str) -> Result<Workload> {
    match name {
        "lr1s" => Ok(linear_road::lr1s()),
        "lr1t" => Ok(linear_road::lr1t()),
        "lr2s" => Ok(linear_road::lr2s()),
        "cm1s" => Ok(cluster_monitoring::cm1s()),
        "cm1t" => Ok(cluster_monitoring::cm1t()),
        "cm2s" => Ok(cluster_monitoring::cm2s()),
        "spj" => Ok(synthetic::spj()),
        other => Err(Error::Config(format!(
            "unknown workload `{other}` (expected one of {ALL:?} or spj)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_workloads_resolve_and_validate() {
        for name in ALL_WITH_SYNTHETIC {
            let w = by_name(name).unwrap();
            w.query.validate().unwrap();
            assert!(!w.query.is_empty());
        }
    }

    #[test]
    fn synthetic_list_is_all_plus_spj() {
        // Every Table III workload is in the full list, `spj` resolves
        // and is only in the full list — no name `by_name` accepts can
        // be skipped by an ALL_WITH_SYNTHETIC loop.
        for name in ALL {
            assert!(ALL_WITH_SYNTHETIC.contains(name), "{name} missing");
        }
        assert!(ALL_WITH_SYNTHETIC.contains(&"spj"));
        assert!(!ALL.contains(&"spj"));
        assert_eq!(ALL_WITH_SYNTHETIC.len(), ALL.len() + 1);
        assert!(by_name("spj").is_ok());
    }

    #[test]
    fn unknown_name_is_config_error() {
        assert!(by_name("nope").is_err());
    }

    #[test]
    fn window_kinds_match_table_three() {
        use crate::engine::window::WindowKind;
        for (name, kind) in [
            ("lr1s", WindowKind::Sliding),
            ("lr1t", WindowKind::Tumbling),
            ("lr2s", WindowKind::Sliding),
            ("cm1s", WindowKind::Sliding),
            ("cm1t", WindowKind::Tumbling),
            ("cm2s", WindowKind::Sliding),
        ] {
            assert_eq!(by_name(name).unwrap().query.window.kind(), kind, "{name}");
        }
    }

    #[test]
    fn streams_generate_rows() {
        use crate::sim::Time;
        for name in ALL {
            let w = by_name(name).unwrap();
            let mut s = w.make_stream(1);
            let data = s.poll(Time::from_secs_f64(2.0));
            assert!(!data.is_empty(), "{name}");
            assert!(data[0].rows() > 0, "{name}");
        }
    }
}
