//! Google Cluster Monitoring workload (Reiss et al. trace schema) — the
//! CM1/CM2 queries of Table III over a synthetic task-event feed.
//!
//! The real trace is proprietary-scale; the generator reproduces its
//! queried fields (jobId, category/priority-class, cpu, eventType) with
//! skewed job popularity (a few hot jobs dominate, as in the trace) and
//! the paper's ingest weight: CM datasets are ~2.5x the LR byte rate
//! (§V-A: 150–200 KB/s vs 60–70 KB/s).

use crate::engine::column::{Column, ColumnBatch, Field, Schema};
use crate::engine::ops::aggregate::AggSpec;
use crate::engine::ops::filter::Predicate;
use crate::engine::window::WindowSpec;
use crate::query::builder::QueryBuilder;
use crate::source::stream::RowGen;
use crate::source::traffic::Traffic;
use crate::util::rng::Rng;
use crate::workloads::Workload;
use std::sync::Arc;
use std::time::Duration;

/// Distinct job ids in flight (GROUP BY jobId cardinality).
pub const NUM_JOBS: i64 = 512;
/// Scheduling categories (GROUP BY category cardinality).
pub const NUM_CATEGORIES: i64 = 8;
/// Event types; the paper's CM2S filters `eventType == 1` (SCHEDULE).
pub const NUM_EVENT_TYPES: i64 = 4;

/// CM rows carry more fields than LR (the trace has dozens); paper CM
/// traffic is ~2.5x LR bytes at the same row rate, so CM uses 2000 rows/s.
pub const ROWS_PER_SEC: usize = 2000;

/// TaskEvents schema (queried fields + representative metric columns).
pub fn schema() -> Arc<Schema> {
    Schema::new(vec![
        Field::f32("timestamp"),
        Field::i32("jobId"),
        Field::i32("category"),
        Field::f32("cpu"),
        Field::f32("mem"),
        Field::f32("disk"),
        Field::i32("eventType"),
        Field::i32("priority"),
    ])
}

/// Task-event generator with Zipf-ish hot-job skew.
pub struct ClusterMonitoringGen {
    rng: Rng,
}

impl ClusterMonitoringGen {
    pub fn new(seed: u64) -> ClusterMonitoringGen {
        ClusterMonitoringGen { rng: Rng::new(seed) }
    }

    fn job(&mut self) -> i32 {
        // 50% of events hit the 16 hottest jobs; the rest are uniform.
        if self.rng.chance(0.5) {
            self.rng.range(0, 16) as i32
        } else {
            self.rng.range(0, NUM_JOBS) as i32
        }
    }
}

impl RowGen for ClusterMonitoringGen {
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
        let mut ts = Vec::with_capacity(rows);
        let mut job = Vec::with_capacity(rows);
        let mut cat = Vec::with_capacity(rows);
        let mut cpu = Vec::with_capacity(rows);
        let mut mem = Vec::with_capacity(rows);
        let mut disk = Vec::with_capacity(rows);
        let mut ev = Vec::with_capacity(rows);
        let mut prio = Vec::with_capacity(rows);
        for _ in 0..rows {
            ts.push(tick as f32);
            job.push(self.job());
            cat.push(self.rng.range(0, NUM_CATEGORIES) as i32);
            cpu.push(self.rng.f32() * 0.5);
            mem.push(self.rng.f32() * 0.3);
            disk.push(self.rng.f32() * 0.1);
            ev.push(self.rng.range(0, NUM_EVENT_TYPES) as i32);
            prio.push(self.rng.range(0, 12) as i32);
        }
        ColumnBatch::new(
            schema(),
            vec![
                Column::F32(ts.into()),
                Column::I32(job.into()),
                Column::I32(cat.into()),
                Column::F32(cpu.into()),
                Column::F32(mem.into()),
                Column::F32(disk.into()),
                Column::I32(ev.into()),
                Column::I32(prio.into()),
            ],
        )
        .expect("CM schema consistent")
    }
}

fn make_gen(seed: u64) -> Box<dyn RowGen> {
    Box::new(ClusterMonitoringGen::new(seed))
}

fn cm_traffic() -> Traffic {
    Traffic::Constant { rows: ROWS_PER_SEC }
}

/// CM1S — windowed per-category CPU total, ordered (Table III):
/// `SELECT timestamp, category, SUM(cpu) as totalCpu
///  FROM TaskEvents [range 60 slide 10]
///  GROUP BY category ORDER BY SUM(cpu)`.
pub fn cm1s() -> Workload {
    let query = QueryBuilder::scan("CM1S")
        .window(WindowSpec::sliding(Duration::from_secs(60), Duration::from_secs(10)))
        .shuffle("category")
        .expand()
        .aggregate(&["category"], vec![AggSpec::sum("cpu", "totalCpu")], None)
        .sort("totalCpu", true)
        .build()
        .expect("CM1S valid");
    Workload::new("CM1S", query, cm_traffic(), make_gen)
}

/// CM1T — the same aggregation over a tumbling [range 60] window.
pub fn cm1t() -> Workload {
    let query = QueryBuilder::scan("CM1T")
        .window(WindowSpec::tumbling(Duration::from_secs(60)))
        .shuffle("category")
        .aggregate(&["category"], vec![AggSpec::sum("cpu", "totalCpu")], None)
        .sort("totalCpu", true)
        .build()
        .expect("CM1T valid");
    Workload::new("CM1T", query, cm_traffic(), make_gen)
}

/// CM2S — per-job average CPU of schedule events (Table III):
/// `SELECT jobId, AVG(cpu) as avgCpu FROM TaskEvents [range 60 slide 5]
///  WHERE (eventType == 1) GROUP BY jobId`.
pub fn cm2s() -> Workload {
    let query = QueryBuilder::scan("CM2S")
        .window(WindowSpec::sliding(Duration::from_secs(60), Duration::from_secs(5)))
        .filter("eventType", Predicate::Eq(1.0))
        .shuffle("jobId") // exchange compacts the filtered rows
        .expand()
        .aggregate(&["jobId"], vec![AggSpec::avg("cpu", "avgCpu")], None)
        .build()
        .expect("CM2S valid");
    Workload::new("CM2S", query, cm_traffic(), make_gen)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_fields_in_range() {
        let mut g = ClusterMonitoringGen::new(1);
        let b = g.generate(3, 4000);
        assert_eq!(b.rows(), 4000);
        let jobs = b.column("jobId").unwrap().as_i32().unwrap();
        assert!(jobs.iter().all(|&j| (0..NUM_JOBS as i32).contains(&j)));
        let cpu = b.column("cpu").unwrap().as_f32().unwrap();
        assert!(cpu.iter().all(|&c| (0.0..=0.5).contains(&c)));
    }

    #[test]
    fn job_popularity_is_skewed() {
        let mut g = ClusterMonitoringGen::new(2);
        let b = g.generate(0, 20_000);
        let jobs = b.column("jobId").unwrap().as_i32().unwrap();
        let hot = jobs.iter().filter(|&&j| j < 16).count() as f64;
        let frac = hot / jobs.len() as f64;
        assert!(frac > 0.4, "hot-job fraction {frac}");
    }

    #[test]
    fn event_filter_selects_quarter() {
        let mut g = ClusterMonitoringGen::new(3);
        let b = g.generate(0, 20_000);
        let ev = b.column("eventType").unwrap().as_i32().unwrap();
        let ones = ev.iter().filter(|&&e| e == 1).count() as f64 / ev.len() as f64;
        assert!((0.2..0.3).contains(&ones), "eventType==1 fraction {ones}");
    }

    #[test]
    fn cm_bytes_heavier_than_lr() {
        use crate::workloads::linear_road::LinearRoadGen;
        use crate::source::stream::RowGen as _;
        let mut cm = ClusterMonitoringGen::new(4);
        let mut lr = LinearRoadGen::new(4);
        let cm_bytes = cm.generate(0, ROWS_PER_SEC).alloc_bytes();
        let lr_bytes = lr.generate(0, 1000).alloc_bytes();
        let ratio = cm_bytes as f64 / lr_bytes as f64;
        assert!((1.8..3.2).contains(&ratio), "CM/LR byte ratio {ratio}");
    }
}
