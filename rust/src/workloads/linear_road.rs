//! Linear Road stream benchmark (Arasu et al., VLDB'04) — the LR1/LR2
//! queries of Table III over a synthetic highway-traffic feed.
//!
//! Generator cardinalities are chosen so the workload reproduces the
//! paper's load regime: ~1000 readings/s with a vehicle pool sized such
//! that the LR1 self-join against a 30 s window amplifies each probe row
//! ~30x — the "fully loading the computing capacity" condition of §V-A.

use crate::engine::column::{Column, ColumnBatch, Field, Schema};
use crate::engine::ops::aggregate::AggSpec;
use crate::engine::ops::filter::Predicate;
use crate::engine::window::WindowSpec;
use crate::query::builder::QueryBuilder;
use crate::source::stream::RowGen;
use crate::source::traffic::Traffic;
use crate::util::rng::Rng;
use crate::workloads::Workload;
use std::sync::Arc;
use std::time::Duration;

/// Vehicles driving concurrently (join-amplification knob).
pub const NUM_VEHICLES: i64 = 1000;
/// Highways / lanes / directions / segments of the benchmark's road net.
pub const NUM_HIGHWAYS: i64 = 4;
pub const NUM_LANES: i64 = 4;
pub const NUM_DIRECTIONS: i64 = 2;
pub const NUM_SEGMENTS: i64 = 96;

/// `SegSpeedStr` schema: position reports.
pub fn schema() -> Arc<Schema> {
    Schema::new(vec![
        Field::f32("timestamp"),
        Field::i32("vehicle"),
        Field::f32("speed"),
        Field::i32("highway"),
        Field::i32("lane"),
        Field::i32("direction"),
        Field::i32("segment"),
    ])
}

/// Position-report generator.
pub struct LinearRoadGen {
    rng: Rng,
}

impl LinearRoadGen {
    pub fn new(seed: u64) -> LinearRoadGen {
        LinearRoadGen { rng: Rng::new(seed) }
    }
}

impl RowGen for LinearRoadGen {
    fn generate(&mut self, tick: u64, rows: usize) -> ColumnBatch {
        let mut ts = Vec::with_capacity(rows);
        let mut vehicle = Vec::with_capacity(rows);
        let mut speed = Vec::with_capacity(rows);
        let mut highway = Vec::with_capacity(rows);
        let mut lane = Vec::with_capacity(rows);
        let mut direction = Vec::with_capacity(rows);
        let mut segment = Vec::with_capacity(rows);
        for _ in 0..rows {
            ts.push(tick as f32);
            vehicle.push(self.rng.range(0, NUM_VEHICLES) as i32);
            // Bimodal speeds: free-flow ~60 mph, congested ~25 mph, so
            // LR2S's HAVING avgSpeed < 40 selects a real subset.
            let congested = self.rng.chance(0.3);
            let base = if congested { 25.0 } else { 60.0 };
            speed.push((base + self.rng.normal_ms(0.0, 8.0)).clamp(0.0, 100.0) as f32);
            highway.push(self.rng.range(0, NUM_HIGHWAYS) as i32);
            lane.push(self.rng.range(0, NUM_LANES) as i32);
            direction.push(self.rng.range(0, NUM_DIRECTIONS) as i32);
            segment.push(self.rng.range(0, NUM_SEGMENTS) as i32);
        }
        ColumnBatch::new(
            schema(),
            vec![
                Column::F32(ts.into()),
                Column::I32(vehicle.into()),
                Column::F32(speed.into()),
                Column::I32(highway.into()),
                Column::I32(lane.into()),
                Column::I32(direction.into()),
                Column::I32(segment.into()),
            ],
        )
        .expect("LR schema consistent")
    }
}

fn make_gen(seed: u64) -> Box<dyn RowGen> {
    Box::new(LinearRoadGen::new(seed))
}

/// LR1S — sliding-window self-join (Table III):
/// `SELECT L.* FROM SegSpeedStr [range 30 slide 5] as A, SegSpeedStr as L
///  WHERE A.vehicle == L.vehicle`.
pub fn lr1s() -> Workload {
    let query = QueryBuilder::scan("LR1S")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5)))
        .join_window("vehicle", "vehicle")
        .select(&[
            "timestamp", "vehicle", "speed", "highway", "lane", "direction", "segment",
        ])
        .build()
        .expect("LR1S valid");
    Workload::new("LR1S", query, Traffic::constant_default(), make_gen)
}

/// LR1T — the same join over a tumbling [range 30] window.
pub fn lr1t() -> Workload {
    let query = QueryBuilder::scan("LR1T")
        .window(WindowSpec::tumbling(Duration::from_secs(30)))
        .join_window("vehicle", "vehicle")
        .select(&[
            "timestamp", "vehicle", "speed", "highway", "lane", "direction", "segment",
        ])
        .build()
        .expect("LR1T valid");
    Workload::new("LR1T", query, Traffic::constant_default(), make_gen)
}

/// LR2S — windowed average-speed aggregation (Table III):
/// `SELECT timestamp, highway, direction, segment, AVG(speed) as avgSpeed
///  FROM SegSpeedStr [range 30 slide 10]
///  GROUP BY (highway, direction, segment) HAVING (avgSpeed < 40.0)`.
pub fn lr2s() -> Workload {
    let query = QueryBuilder::scan("LR2S")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(10)))
        .shuffle("segment")
        .expand()
        .aggregate(
            &["highway", "direction", "segment"],
            vec![AggSpec::avg("speed", "avgSpeed")],
            Some(("avgSpeed", Predicate::Lt(40.0))),
        )
        .build()
        .expect("LR2S valid");
    Workload::new("LR2S", query, Traffic::constant_default(), make_gen)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_respects_cardinalities() {
        let mut g = LinearRoadGen::new(1);
        let b = g.generate(5, 2000);
        assert_eq!(b.rows(), 2000);
        let vehicles = b.column("vehicle").unwrap().as_i32().unwrap();
        assert!(vehicles.iter().all(|&v| (0..NUM_VEHICLES as i32).contains(&v)));
        let speeds = b.column("speed").unwrap().as_f32().unwrap();
        assert!(speeds.iter().all(|&s| (0.0..=100.0).contains(&s)));
        let ts = b.column("timestamp").unwrap().as_f32().unwrap();
        assert!(ts.iter().all(|&t| t == 5.0));
    }

    #[test]
    fn join_amplification_in_target_band() {
        // 30 s of window at 1000 rows/s vs 1 s of probe: each probe row
        // should match ~30 window rows (±40 %) — the §V-A load regime.
        use crate::engine::ops::hash_join;
        let mut g = LinearRoadGen::new(2);
        let window = g.generate(0, 30_000);
        let probe = g.generate(30, 1000);
        let joined = hash_join(&probe, &window, "vehicle", "vehicle").unwrap();
        let amp = joined.rows() as f64 / probe.rows() as f64;
        assert!((18.0..42.0).contains(&amp), "amplification {amp}");
    }

    #[test]
    fn lr2s_having_selects_congested_subset() {
        use crate::engine::ops::{hash_aggregate, AggSpec};
        let mut g = LinearRoadGen::new(3);
        let b = g.generate(0, 20_000);
        let agg = hash_aggregate(
            &b,
            &["highway", "direction", "segment"],
            &[AggSpec::avg("speed", "avgSpeed")],
            Some(("avgSpeed", Predicate::Lt(40.0))),
        )
        .unwrap();
        let kept = agg.live_rows();
        let total = agg.rows();
        assert!(kept > 0, "HAVING kept nothing");
        assert!(kept < total, "HAVING kept everything ({kept}/{total})");
    }

    #[test]
    fn speeds_are_bimodal_around_threshold() {
        let mut g = LinearRoadGen::new(4);
        let b = g.generate(0, 10_000);
        let speeds = b.column("speed").unwrap().as_f32().unwrap();
        let slow = speeds.iter().filter(|&&s| s < 40.0).count() as f64;
        let frac = slow / speeds.len() as f64;
        assert!((0.2..0.45).contains(&frac), "slow fraction {frac}");
    }
}
