//! Synthetic select-project-join query — the micro-benchmark of Figs. 2
//! and 5 (PCIe overhead ratios and normalized execution times across
//! batch sizes and CPU/GPU mapping scenarios).

use crate::engine::column::{Column, ColumnBatch, Field, Schema};
use crate::engine::ops::filter::Predicate;
use crate::engine::window::WindowSpec;
use crate::query::builder::QueryBuilder;
use crate::source::stream::RowGen;
use crate::source::traffic::Traffic;
use crate::util::rng::Rng;
use crate::workloads::Workload;
use std::sync::Arc;
use std::time::Duration;

/// Join-key cardinality (modest amplification: ~2 matches per probe row
/// against an equal-sized build side).
pub const NUM_KEYS: i64 = 4096;

pub fn schema() -> Arc<Schema> {
    Schema::new(vec![
        Field::f32("key"),
        Field::f32("a"),
        Field::f32("b"),
        Field::i32("jk"),
    ])
}

/// Uniform random generator for the SPJ columns.
pub struct SyntheticGen {
    rng: Rng,
}

impl SyntheticGen {
    pub fn new(seed: u64) -> SyntheticGen {
        SyntheticGen { rng: Rng::new(seed) }
    }

    /// A batch of approximately `bytes` total size (17 B/row).
    pub fn batch_of_bytes(&mut self, bytes: usize) -> ColumnBatch {
        let rows = (bytes / 17).max(1);
        self.generate(0, rows)
    }
}

impl RowGen for SyntheticGen {
    fn generate(&mut self, _tick: u64, rows: usize) -> ColumnBatch {
        let mut key = Vec::with_capacity(rows);
        let mut a = Vec::with_capacity(rows);
        let mut b = Vec::with_capacity(rows);
        let mut jk = Vec::with_capacity(rows);
        for _ in 0..rows {
            key.push(self.rng.f32());
            a.push(self.rng.f32());
            b.push(self.rng.f32());
            jk.push(self.rng.range(0, NUM_KEYS) as i32);
        }
        ColumnBatch::new(
            schema(),
            vec![
                Column::F32(key.into()),
                Column::F32(a.into()),
                Column::F32(b.into()),
                Column::I32(jk.into()),
            ],
        )
        .expect("SPJ schema consistent")
    }
}

fn make_gen(seed: u64) -> Box<dyn RowGen> {
    Box::new(SyntheticGen::new(seed))
}

/// The select-project-join chain used by Figs. 2/5:
/// scan → filter(key ≥ 0.2) → project(a+b) → join on jk.
pub fn spj() -> Workload {
    let query = QueryBuilder::scan("SPJ")
        .window(WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(10)))
        .filter("key", Predicate::Ge(0.2))
        .project_affine("a", "b", 1.0, 1.0, "ab")
        .join_window("jk", "jk")
        .build()
        .expect("SPJ valid");
    Workload::new("SPJ", query, Traffic::constant_default(), make_gen)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_of_bytes_hits_target() {
        let mut g = SyntheticGen::new(1);
        let b = g.batch_of_bytes(100 * 1024);
        let ratio = b.alloc_bytes() as f64 / (100.0 * 1024.0);
        assert!((0.9..1.1).contains(&ratio), "{ratio}");
    }

    #[test]
    fn filter_selectivity_about_eighty_percent() {
        use crate::engine::ops::filter;
        let mut g = SyntheticGen::new(2);
        let b = g.generate(0, 10_000);
        let f = filter(&b, "key", Predicate::Ge(0.2)).unwrap();
        let frac = f.live_rows() as f64 / b.rows() as f64;
        assert!((0.75..0.85).contains(&frac), "{frac}");
    }

    #[test]
    fn spj_query_shape() {
        use crate::query::dag::OpKind;
        let w = spj();
        let kinds: Vec<OpKind> = w.query.traverse().map(|o| o.spec.kind()).collect();
        assert_eq!(
            kinds,
            vec![OpKind::Scan, OpKind::Filter, OpKind::Project, OpKind::Join]
        );
    }
}
