//! Distributed runtime: the paper's testbed is one master + two worker
//! nodes with two executors each (§V-A). This module models that
//! topology: a [`ClusterSpec`] of executors (cores + GPUs each), the
//! master's partition dispatch, network exchange at shuffle boundaries,
//! and straggler-aware barrier timing.
//!
//! The single-executor default used by the paper-figure benches is the
//! degenerate `ClusterSpec::single()`; `ClusterSpec::paper()` is the
//! 4-executor testbed. `benches`/`examples` exercise scale-out via
//! [`crate::cluster::exec::execute_on_cluster`].

pub mod exec;
pub mod faults;
pub mod topology;

pub use exec::{
    execute_on_cluster, execute_on_cluster_faulted, execute_on_cluster_with_occupancy,
    ClusterOutcome,
};
pub use faults::{ExecState, ExecutorHealth, FaultEvent, FaultKind, FaultPlan, RoundFaults};
pub use topology::{shard_of, ClusterSpec, DeviceTopology, ExecutorSpec, NetworkModel};
