//! Cluster topology and network model.

use crate::error::{Error, Result};
use std::time::Duration;

/// One executor: a JVM-analog process owning CPU cores and GPUs
/// (the paper's executors own 12 cores + 1 GPU each).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecutorSpec {
    pub cores: usize,
    pub gpus: usize,
}

impl Default for ExecutorSpec {
    fn default() -> Self {
        ExecutorSpec { cores: 12, gpus: 1 }
    }
}

/// Inter-executor network (the worker-node NICs).
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-message latency (RPC + serialization setup).
    pub latency: Duration,
    /// Effective bandwidth per executor pair, bytes/s.
    pub bandwidth: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        // 10 GbE with Spark serialization overhead ≈ 300 MB/s effective.
        NetworkModel {
            latency: Duration::from_micros(500),
            bandwidth: 300.0 * 1024.0 * 1024.0,
        }
    }
}

impl NetworkModel {
    /// Time to move `bytes` across the network in one exchange step.
    pub fn transfer(&self, bytes: f64) -> Duration {
        self.latency + Duration::from_secs_f64(bytes / self.bandwidth)
    }
}

/// The full cluster.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub executors: Vec<ExecutorSpec>,
    pub network: NetworkModel,
    /// Per-batch master coordination overhead (task dispatch, barrier,
    /// commit) — grows mildly with executor count.
    pub coordination_per_executor: Duration,
}

impl ClusterSpec {
    /// One executor — the per-executor model the paper-figure benches
    /// calibrate against.
    pub fn single() -> ClusterSpec {
        ClusterSpec {
            executors: vec![ExecutorSpec::default()],
            network: NetworkModel::default(),
            coordination_per_executor: Duration::from_millis(20),
        }
    }

    /// The paper's testbed: 2 worker nodes x 2 executors (§V-A).
    pub fn paper() -> ClusterSpec {
        ClusterSpec {
            executors: vec![ExecutorSpec::default(); 4],
            ..ClusterSpec::single()
        }
    }

    /// Homogeneous cluster of `n` default executors.
    pub fn of(n: usize) -> ClusterSpec {
        ClusterSpec {
            executors: vec![ExecutorSpec::default(); n],
            ..ClusterSpec::single()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.executors.is_empty() {
            return Err(Error::Config("cluster needs at least one executor".into()));
        }
        for (i, e) in self.executors.iter().enumerate() {
            if e.cores == 0 || e.gpus == 0 {
                return Err(Error::Config(format!(
                    "executor {i} must have cores and gpus"
                )));
            }
        }
        Ok(())
    }

    pub fn total_cores(&self) -> usize {
        self.executors.iter().map(|e| e.cores).sum()
    }

    pub fn total_gpus(&self) -> usize {
        self.executors.iter().map(|e| e.gpus).sum()
    }

    /// Master-side per-batch coordination time.
    pub fn coordination(&self) -> Duration {
        Duration::from_secs_f64(
            self.coordination_per_executor.as_secs_f64() * self.executors.len() as f64,
        )
    }

    /// The surviving cluster after failures: the executors whose
    /// physical ids appear in `active`, same network and per-executor
    /// coordination cost. Row shares, shuffle fractions, and barrier
    /// overhead all rescale to the survivor set.
    pub fn subset(&self, active: &[usize]) -> ClusterSpec {
        ClusterSpec {
            executors: active.iter().map(|&e| self.executors[e]).collect(),
            network: self.network,
            coordination_per_executor: self.coordination_per_executor,
        }
    }
}

/// The shard (source group) a source belongs to under the sharded
/// session runtime: sources deal round-robin, `source % shards`. Kept
/// here with the cluster shape because it is the one placement rule
/// every layer (session loops, metrics, quotas, tests) must agree on —
/// and it is intentionally independent of the executor count, so
/// re-sharding never re-partitions the data plane.
pub fn shard_of(source: usize, shards: usize) -> usize {
    assert!(shards > 0, "shard_of needs at least one shard");
    source % shards
}

/// The device shape a scheduling round plans against: one entry per
/// executor (its cores and GPUs). This is the **source of truth** for
/// joint planning — `schedule::plan_joint` simulates one GPU timeline
/// per executor of this topology, and the session allocates one
/// execution [`GpuTimeline`](crate::query::exec::GpuTimeline) per entry.
/// A single-node session is the 1-executor special case
/// ([`DeviceTopology::single`]); a cluster session derives its topology
/// from the [`ClusterSpec`] ([`DeviceTopology::from_cluster`]), so the
/// planner's simulated device layout and the executor's arbitration can
/// never disagree.
#[derive(Clone, Debug)]
pub struct DeviceTopology {
    pub executors: Vec<ExecutorSpec>,
    /// Per-executor GPU health. `false` means the executor is alive but
    /// its GPU device has faulted: the scheduler charges its GPU-mapped
    /// ops at CPU cost (no segments, no transfers) and execution runs
    /// its share on a CPU-demoted plan. Always `executors.len()` long.
    pub gpu_ok: Vec<bool>,
}

impl DeviceTopology {
    /// Single-node topology: one executor owning all of the session's
    /// cores and GPUs.
    pub fn single(cores: usize, gpus: usize) -> DeviceTopology {
        DeviceTopology {
            executors: vec![ExecutorSpec { cores, gpus }],
            gpu_ok: vec![true],
        }
    }

    /// The topology a cluster session executes on — one entry per
    /// executor of the spec.
    pub fn from_cluster(spec: &ClusterSpec) -> DeviceTopology {
        DeviceTopology {
            gpu_ok: vec![true; spec.executors.len()],
            executors: spec.executors.clone(),
        }
    }

    pub fn num_executors(&self) -> usize {
        self.executors.len()
    }

    pub fn total_cores(&self) -> usize {
        self.executors.iter().map(|e| e.cores).sum()
    }

    /// Whether executor `e`'s GPU device is usable this round.
    pub fn gpu_usable(&self, e: usize) -> bool {
        self.gpu_ok[e]
    }

    /// Mark executor `e`'s GPU device as faulted: it keeps its cores
    /// (and its row share) but plans and executes CPU-only.
    pub fn degrade_gpu(&mut self, e: usize) {
        self.gpu_ok[e] = false;
    }

    /// The surviving topology after failures: the executors whose
    /// indices appear in `active`, keeping each survivor's GPU health.
    pub fn subset(&self, active: &[usize]) -> DeviceTopology {
        DeviceTopology {
            executors: active.iter().map(|&e| self.executors[e]).collect(),
            gpu_ok: active.iter().map(|&e| self.gpu_ok[e]).collect(),
        }
    }

    /// Fraction of a micro-batch's rows executor `e` processes (the
    /// cluster splits proportionally to core counts; a single node takes
    /// everything).
    pub fn row_share(&self, e: usize) -> f64 {
        let total = self.total_cores();
        if total == 0 {
            return 0.0;
        }
        self.executors[e].cores as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let c = ClusterSpec::paper();
        assert_eq!(c.executors.len(), 4);
        assert_eq!(c.total_cores(), 48);
        assert_eq!(c.total_gpus(), 4);
        c.validate().unwrap();
    }

    #[test]
    fn empty_cluster_invalid() {
        let c = ClusterSpec { executors: vec![], ..ClusterSpec::single() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn zero_core_executor_invalid() {
        let c = ClusterSpec {
            executors: vec![ExecutorSpec { cores: 0, gpus: 1 }],
            ..ClusterSpec::single()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn network_transfer_scales_with_bytes() {
        let n = NetworkModel::default();
        let small = n.transfer(1024.0);
        let big = n.transfer(100.0 * 1024.0 * 1024.0);
        assert!(big > small);
        assert!(big.as_secs_f64() > 0.3); // 100 MB at 300 MB/s
    }

    #[test]
    fn coordination_grows_with_executors() {
        assert!(ClusterSpec::of(4).coordination() > ClusterSpec::of(1).coordination());
    }

    #[test]
    fn topology_row_shares_sum_to_one() {
        let t = DeviceTopology::from_cluster(&ClusterSpec::paper());
        assert_eq!(t.num_executors(), 4);
        assert_eq!(t.total_cores(), 48);
        let sum: f64 = (0..t.num_executors()).map(|e| t.row_share(e)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_node_topology_is_one_executor() {
        let t = DeviceTopology::single(12, 2);
        assert_eq!(t.num_executors(), 1);
        assert_eq!(t.total_cores(), 12);
        assert_eq!(t.executors[0].gpus, 2);
        assert_eq!(t.row_share(0), 1.0);
        assert!(t.gpu_usable(0));
    }

    #[test]
    fn topology_subset_keeps_survivor_health() {
        let mut t = DeviceTopology::from_cluster(&ClusterSpec::paper());
        t.degrade_gpu(2);
        let sub = t.subset(&[0, 2, 3]);
        assert_eq!(sub.num_executors(), 3);
        assert_eq!(sub.total_cores(), 36);
        assert!(sub.gpu_usable(0));
        assert!(!sub.gpu_usable(1)); // physical executor 2
        assert!(sub.gpu_usable(2));
        let sum: f64 = (0..sub.num_executors()).map(|e| sub.row_share(e)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn shard_assignment_is_round_robin() {
        assert_eq!(
            (0..6).map(|s| shard_of(s, 4)).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 0, 1]
        );
        assert!((0..8).all(|s| shard_of(s, 1) == 0));
    }

    #[test]
    fn cluster_subset_rescales_coordination_and_shuffle_shape() {
        let c = ClusterSpec::paper();
        let sub = c.subset(&[1, 3]);
        assert_eq!(sub.executors.len(), 2);
        assert_eq!(sub.total_cores(), 24);
        assert!(sub.coordination() < c.coordination());
        sub.validate().unwrap();
    }
}
