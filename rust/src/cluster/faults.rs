//! Deterministic executor fault injection and health tracking.
//!
//! Failures in the simulated cluster are *planned*, not random at run
//! time: a [`FaultPlan`] schedules crashes, GPU-device faults, transient
//! stalls, and rejoins at specific (round, executor) points — either
//! hand-built or generated from a seed — so every fault scenario is
//! exactly reproducible and differential-testable against a fault-free
//! oracle run.
//!
//! [`ExecutorHealth`] is the session's view of the plan: a per-executor
//! state machine
//!
//! ```text
//!            GpuFail                  Crash
//!   Up ───────────────► GpuDegraded    │
//!   ▲  ◄───────────────      │         ▼
//!   │       Rejoin           └──────► Down
//!   │                         Crash    │ Rejoin
//!   │   probation expires              ▼
//!   └───────────────────────── Probation{remaining}
//!                                      │ any failure
//!                                      └──────► Down
//! ```
//!
//! Crashes and stalls surface as a failed *attempt* of the round they
//! hit (the executor's share is lost mid-execution); the session then
//! transitions health, re-plans on the survivors, and retries under its
//! backoff budget. A stall is transient — the executor stays up and the
//! retry runs on the full topology — while a crash removes the executor
//! until a `Rejoin` event puts it back on probation. GPU faults do not
//! fail the round at all: the executor keeps its cores and its row
//! share, and plans/executes CPU-only (graceful degradation).

use crate::coordinator::metrics::ExecutorHealthStats;
use crate::util::rng::Rng;

/// What goes wrong (or right again) at one executor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Process loss: the executor's share fails this round and the
    /// executor leaves the topology until a [`FaultKind::Rejoin`].
    Crash,
    /// The GPU device fails but the process survives: the executor
    /// plans and executes CPU-only from this round on.
    GpuFail,
    /// Transient hiccup (GC pause, network blip): the executor's share
    /// fails exactly one attempt, then the executor is healthy again.
    Stall,
    /// A down executor comes back (or a faulted GPU is serviced). Down
    /// executors re-enter through probation; health-gated — failing
    /// again during probation sends them back down.
    Rejoin,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::GpuFail => "gpu-fail",
            FaultKind::Stall => "stall",
            FaultKind::Rejoin => "rejoin",
        }
    }
}

/// One scheduled fault: `kind` hits `executor` when the session begins
/// round `round` (1-based, matching `BatchRecord::round`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub round: usize,
    pub executor: usize,
    pub kind: FaultKind,
}

/// A deterministic schedule of executor faults for a whole run.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// An empty plan (no faults — the fault-free oracle).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Schedule a crash of `executor` at `round`.
    pub fn crash(mut self, round: usize, executor: usize) -> FaultPlan {
        self.events.push(FaultEvent { round, executor, kind: FaultKind::Crash });
        self
    }

    /// Schedule a GPU-device fault of `executor` at `round`.
    pub fn gpu_fail(mut self, round: usize, executor: usize) -> FaultPlan {
        self.events.push(FaultEvent { round, executor, kind: FaultKind::GpuFail });
        self
    }

    /// Schedule a one-attempt transient stall of `executor` at `round`.
    pub fn stall(mut self, round: usize, executor: usize) -> FaultPlan {
        self.events.push(FaultEvent { round, executor, kind: FaultKind::Stall });
        self
    }

    /// Schedule a rejoin of `executor` at `round`.
    pub fn rejoin(mut self, round: usize, executor: usize) -> FaultPlan {
        self.events.push(FaultEvent { round, executor, kind: FaultKind::Rejoin });
        self
    }

    /// A seeded random plan of `events` faults over `rounds` rounds of an
    /// `executors`-wide cluster. Survivable by construction: executor 0
    /// never crashes (so every round has a survivor to re-plan on) and
    /// every crash schedules a rejoin 1–3 rounds later. On a single-
    /// executor topology crashes degenerate to stalls for the same
    /// reason. Deterministic in `seed`.
    pub fn seeded(seed: u64, rounds: usize, executors: usize, events: usize) -> FaultPlan {
        assert!(rounds > 0 && executors > 0);
        let mut rng = Rng::new(seed ^ 0xfa07_71a5_u64);
        let mut plan = FaultPlan::new();
        for _ in 0..events {
            let round = 1 + rng.below(rounds as u64) as usize;
            match rng.below(3) {
                0 => {
                    let e = rng.below(executors as u64) as usize;
                    plan = plan.stall(round, e);
                }
                1 => {
                    let e = rng.below(executors as u64) as usize;
                    plan = plan.gpu_fail(round, e);
                }
                _ => {
                    if executors == 1 {
                        plan = plan.stall(round, 0);
                    } else {
                        let e = 1 + rng.below(executors as u64 - 1) as usize;
                        let back = round + 1 + rng.below(3) as usize;
                        plan = plan.crash(round, e).rejoin(back, e);
                    }
                }
            }
        }
        plan
    }

    /// Every scheduled event that fires at `round`.
    pub fn events_at(&self, round: usize) -> impl Iterator<Item = &FaultEvent> {
        self.events.iter().filter(move |e| e.round == round)
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Per-executor health state (see the module-level state machine).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecState {
    /// Healthy: full member of the topology, GPU usable.
    Up,
    /// Alive but the GPU device is faulted: plans and executes CPU-only.
    GpuDegraded,
    /// Crashed: excluded from the topology entirely.
    Down,
    /// Recently rejoined: active (full member) but health-gated — any
    /// failure while `remaining > 0` sends the executor back to `Down`.
    Probation {
        /// Rounds of probation left.
        remaining: usize,
    },
}

impl ExecState {
    pub fn name(&self) -> &'static str {
        match self {
            ExecState::Up => "up",
            ExecState::GpuDegraded => "gpu-degraded",
            ExecState::Down => "down",
            ExecState::Probation { .. } => "probation",
        }
    }
}

/// The faults a single execution attempt must observe, in *local*
/// indices of the (possibly degraded) cluster spec being executed.
#[derive(Clone, Debug, Default)]
pub struct RoundFaults {
    /// Executors whose share fails mid-execution this attempt.
    pub fail: Vec<usize>,
    /// Executors whose share runs the CPU-demoted plan (GPU faulted).
    pub cpu_only: Vec<usize>,
}

impl RoundFaults {
    pub fn is_clean(&self) -> bool {
        self.fail.is_empty() && self.cpu_only.is_empty()
    }
}

/// The session's failure detector: applies a [`FaultPlan`] round by
/// round, tracks each physical executor's [`ExecState`], and tells the
/// round loop which executors fail the next attempt, which survive, and
/// which are GPU-degraded.
#[derive(Clone, Debug)]
pub struct ExecutorHealth {
    states: Vec<ExecState>,
    plan: FaultPlan,
    probation_rounds: usize,
    /// Faults armed for the current round's next attempt (consumed by
    /// [`ExecutorHealth::attempt_faults`]; crashes/stalls fail exactly
    /// one attempt, then state transitions take over).
    pending: Vec<(usize, FaultKind)>,
    /// The faults the *last* drained attempt observed, kept so
    /// [`ExecutorHealth::note_attempt_failed`] can transition state.
    last_attempt: Vec<(usize, FaultKind)>,
    stats: Vec<ExecutorHealthStats>,
}

impl ExecutorHealth {
    /// A detector over `executors` physical executors following `plan`.
    pub fn new(executors: usize, plan: FaultPlan, probation_rounds: usize) -> ExecutorHealth {
        ExecutorHealth {
            states: vec![ExecState::Up; executors],
            plan,
            probation_rounds,
            pending: Vec::new(),
            last_attempt: Vec::new(),
            stats: (0..executors)
                .map(|e| ExecutorHealthStats { executor: e, ..ExecutorHealthStats::default() })
                .collect(),
        }
    }

    /// Advance to `round`: expire probation, then arm this round's
    /// scheduled faults. Call once per round, before the first attempt.
    pub fn begin_round(&mut self, round: usize) {
        self.pending.clear();
        self.last_attempt.clear();
        for st in &mut self.states {
            if let ExecState::Probation { remaining } = st {
                *st = if *remaining <= 1 {
                    ExecState::Up
                } else {
                    ExecState::Probation { remaining: *remaining - 1 }
                };
            }
        }
        // Collect first (the plan is borrowed), then apply.
        let fired: Vec<FaultEvent> = self.plan.events_at(round).copied().collect();
        for ev in fired {
            let e = ev.executor;
            if e >= self.states.len() {
                continue; // plan written for a wider cluster: inert
            }
            match ev.kind {
                FaultKind::Crash => {
                    if self.states[e] != ExecState::Down {
                        self.pending.push((e, FaultKind::Crash));
                        self.stats[e].crashes += 1;
                    }
                }
                FaultKind::Stall => {
                    if self.states[e] != ExecState::Down {
                        self.pending.push((e, FaultKind::Stall));
                        self.stats[e].stalls += 1;
                    }
                }
                FaultKind::GpuFail => match self.states[e] {
                    ExecState::Down | ExecState::GpuDegraded => {}
                    _ => {
                        self.states[e] = ExecState::GpuDegraded;
                        self.stats[e].gpu_faults += 1;
                    }
                },
                FaultKind::Rejoin => match self.states[e] {
                    ExecState::Down => {
                        self.states[e] = if self.probation_rounds == 0 {
                            ExecState::Up
                        } else {
                            ExecState::Probation { remaining: self.probation_rounds }
                        };
                        self.stats[e].rejoins += 1;
                    }
                    ExecState::GpuDegraded => {
                        // Device serviced.
                        self.states[e] = ExecState::Up;
                        self.stats[e].rejoins += 1;
                    }
                    _ => {}
                },
            }
        }
    }

    /// Drain the faults armed for the next attempt: the physical
    /// executor ids that must fail it. Empty on retries (a crash keeps
    /// failing through topology exclusion, not repeated injection).
    pub fn attempt_faults(&mut self) -> Vec<usize> {
        self.last_attempt = std::mem::take(&mut self.pending);
        self.last_attempt.iter().map(|&(e, _)| e).collect()
    }

    /// The attempt whose faults [`ExecutorHealth::attempt_faults`] last
    /// returned has failed: transition state. Crashes go `Down`; stalls
    /// are transient unless the executor was on probation (health-gated
    /// rejoin: a probationary failure sends it back down).
    pub fn note_attempt_failed(&mut self) {
        for (e, kind) in std::mem::take(&mut self.last_attempt) {
            match kind {
                FaultKind::Crash => self.states[e] = ExecState::Down,
                FaultKind::Stall => {
                    if matches!(self.states[e], ExecState::Probation { .. }) {
                        self.states[e] = ExecState::Down;
                    }
                }
                _ => {}
            }
        }
    }

    /// Physical ids of the executors currently in the topology.
    pub fn active(&self) -> Vec<usize> {
        (0..self.states.len()).filter(|&e| self.states[e] != ExecState::Down).collect()
    }

    /// Whether physical executor `e`'s GPU device is usable.
    pub fn gpu_ok(&self, e: usize) -> bool {
        self.states[e] != ExecState::GpuDegraded
    }

    /// Any executor not fully `Up` (the round runs on a degraded
    /// topology).
    pub fn is_degraded(&self) -> bool {
        self.states.iter().any(|s| *s != ExecState::Up)
    }

    pub fn state(&self, e: usize) -> ExecState {
        self.states[e]
    }

    /// Per-executor fault counters accumulated so far.
    pub fn stats(&self) -> Vec<ExecutorHealthStats> {
        let mut out = self.stats.clone();
        for (e, s) in out.iter_mut().enumerate() {
            s.state = self.states[e].name().to_string();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_fails_one_attempt_then_excludes_executor() {
        let plan = FaultPlan::new().crash(2, 1);
        let mut h = ExecutorHealth::new(3, plan, 2);
        h.begin_round(1);
        assert!(h.attempt_faults().is_empty());
        assert_eq!(h.active(), vec![0, 1, 2]);

        h.begin_round(2);
        assert_eq!(h.attempt_faults(), vec![1]);
        h.note_attempt_failed();
        assert_eq!(h.active(), vec![0, 2]);
        // Retry of the same round injects nothing new.
        assert!(h.attempt_faults().is_empty());
        assert_eq!(h.state(1), ExecState::Down);
    }

    #[test]
    fn stall_is_transient() {
        let plan = FaultPlan::new().stall(1, 0);
        let mut h = ExecutorHealth::new(2, plan, 2);
        h.begin_round(1);
        assert_eq!(h.attempt_faults(), vec![0]);
        h.note_attempt_failed();
        assert_eq!(h.state(0), ExecState::Up);
        assert_eq!(h.active(), vec![0, 1]);
    }

    #[test]
    fn rejoin_goes_through_probation_and_is_health_gated() {
        let plan = FaultPlan::new().crash(1, 1).rejoin(3, 1).stall(4, 1);
        let mut h = ExecutorHealth::new(2, plan.clone(), 2);
        h.begin_round(1);
        h.attempt_faults();
        h.note_attempt_failed();
        assert_eq!(h.state(1), ExecState::Down);

        h.begin_round(2);
        assert_eq!(h.active(), vec![0]);

        h.begin_round(3);
        assert_eq!(h.state(1), ExecState::Probation { remaining: 2 });
        assert_eq!(h.active(), vec![0, 1]);
        assert!(h.is_degraded());

        // Stall during probation kills the rejoin.
        h.begin_round(4);
        assert_eq!(h.state(1), ExecState::Probation { remaining: 1 });
        assert_eq!(h.attempt_faults(), vec![1]);
        h.note_attempt_failed();
        assert_eq!(h.state(1), ExecState::Down);

        // Without the probationary stall, probation expires back to Up.
        let mut h2 = ExecutorHealth::new(2, FaultPlan::new().crash(1, 1).rejoin(3, 1), 2);
        h2.begin_round(1);
        h2.attempt_faults();
        h2.note_attempt_failed();
        for r in 2..=5 {
            h2.begin_round(r);
        }
        assert_eq!(h2.state(1), ExecState::Up);
        assert!(!h2.is_degraded());
    }

    #[test]
    fn gpu_fault_degrades_without_failing_and_rejoin_services_it() {
        let plan = FaultPlan::new().gpu_fail(2, 0).rejoin(4, 0);
        let mut h = ExecutorHealth::new(2, plan, 2);
        h.begin_round(1);
        assert!(h.gpu_ok(0));
        h.begin_round(2);
        assert!(h.attempt_faults().is_empty(), "gpu fault must not fail the round");
        assert!(!h.gpu_ok(0));
        assert!(h.gpu_ok(1));
        assert_eq!(h.active(), vec![0, 1]);
        assert!(h.is_degraded());
        h.begin_round(4);
        assert!(h.gpu_ok(0));
        let stats = h.stats();
        assert_eq!(stats[0].gpu_faults, 1);
        assert_eq!(stats[0].rejoins, 1);
    }

    #[test]
    fn seeded_plans_are_deterministic_and_survivable() {
        for seed in [1u64, 7, 42] {
            let a = FaultPlan::seeded(seed, 8, 3, 6);
            let b = FaultPlan::seeded(seed, 8, 3, 6);
            assert_eq!(a.events(), b.events());
            assert!(!a.is_empty());
            for ev in a.events() {
                assert!(ev.round >= 1);
                assert!(ev.executor < 3);
                if ev.kind == FaultKind::Crash {
                    assert_ne!(ev.executor, 0, "executor 0 must never crash");
                    assert!(
                        a.events().iter().any(|r| r.kind == FaultKind::Rejoin
                            && r.executor == ev.executor
                            && r.round > ev.round),
                        "every seeded crash schedules a rejoin"
                    );
                }
            }
        }
        assert_ne!(
            FaultPlan::seeded(1, 8, 3, 6).events(),
            FaultPlan::seeded(2, 8, 3, 6).events()
        );
    }

    #[test]
    fn single_executor_seeded_plans_never_crash() {
        for seed in 0..16u64 {
            let p = FaultPlan::seeded(seed, 6, 1, 8);
            assert!(p.events().iter().all(|e| e.kind != FaultKind::Crash));
        }
    }

    #[test]
    fn events_off_the_end_of_the_cluster_are_inert() {
        let plan = FaultPlan::new().crash(1, 9);
        let mut h = ExecutorHealth::new(2, plan, 1);
        h.begin_round(1);
        assert!(h.attempt_faults().is_empty());
        assert_eq!(h.active(), vec![0, 1]);
    }
}
