//! Cluster-level execution: the master splits a micro-batch across
//! executors; each executor runs the planned operator chain on its share
//! (through the same [`crate::query::exec`] engine); shuffle boundaries
//! pay a network all-to-all; the batch completes at the slowest executor
//! (barrier), plus master coordination.
//!
//! Shares are chunk-list views of the input ([`ChunkedBatch::slice`])
//! and executor outputs are reassembled by chunk appends
//! ([`ChunkedBatch::extend`]) — the cluster path copies no rows on
//! either side of the barrier. Branch-sink outputs are merged the same
//! way and surfaced in [`ClusterOutcome::branch_results`] (they used to
//! be dropped on the floor).

use crate::config::ExecBackend;
use crate::cluster::faults::RoundFaults;
use crate::cluster::topology::ClusterSpec;
use crate::devices::model::DeviceModel;
use crate::engine::chunked::ChunkedBatch;
use crate::error::{Error, Result};
use crate::query::dag::{OpKind, Query};
use crate::query::exec::{self, ExecEnv, ExecOpts, ExecOutcome, GpuTimeline, NoContention};
use crate::query::fuse;
use crate::query::physical::PhysicalPlan;
use crate::runtime::client::Runtime;
use std::sync::Arc;
use std::time::Duration;

/// Result of one cluster-wide batch execution.
#[derive(Debug)]
pub struct ClusterOutcome {
    /// Primary-sink rows from all executors (chunk-appended, in
    /// executor order — no materializing concat).
    pub result: ChunkedBatch,
    /// Branch-sink outputs merged across executors, as `(op_id, batch)`
    /// in ascending op id — the same shape as
    /// [`ExecOutcome::branch_results`], so single-node and cluster runs
    /// deliver identical branch outputs.
    pub branch_results: Vec<(usize, ChunkedBatch)>,
    /// Wall/simulated processing time: max executor chain + exchanges +
    /// coordination.
    pub proc: Duration,
    /// Slowest executor's chain time (straggler).
    pub straggler: Duration,
    /// Total network exchange time charged.
    pub network: Duration,
    /// Per-executor outcomes (traces etc.).
    pub per_executor: Vec<ExecOutcome>,
}

/// Execute `query` over `input` on a cluster.
///
/// The input is row-split across executors proportionally to their core
/// counts; `window` (join build side) is broadcast — every executor joins
/// against the full window state, exactly as Spark broadcasts small build
/// sides / replicates window state per partition.
pub fn execute_on_cluster(
    cluster: &ClusterSpec,
    query: &Query,
    plan: &PhysicalPlan,
    input: impl Into<ChunkedBatch>,
    window: Option<&ChunkedBatch>,
    model: &DeviceModel,
    backend: ExecBackend,
    runtime: Option<&Runtime>,
) -> Result<ClusterOutcome> {
    execute_on_cluster_with_occupancy(
        cluster, query, plan, input, window, model, backend, runtime, None,
    )
}

/// [`execute_on_cluster`] routing a session round's *joint* plan per
/// executor: each executor's GPU is a shared device across the
/// concurrent queries of one scheduling round (all sources), so the
/// caller hands one [`GpuTimeline`] per executor (`timelines.len() ==
/// cluster.executors.len()` — the same per-executor bank
/// `schedule::plan_joint` simulated over the round's
/// [`DeviceTopology`](crate::cluster::DeviceTopology)) and this
/// function charges executor `i`'s simulated GPU ops against
/// `timelines[i]`. Cluster rounds consume joint, topology-aware plans —
/// the round's queries call this in the scheduler's grant order against
/// one shared bank. With `None` every executor sees an idle device (the
/// single-query behavior).
#[allow(clippy::too_many_arguments)]
pub fn execute_on_cluster_with_occupancy(
    cluster: &ClusterSpec,
    query: &Query,
    plan: &PhysicalPlan,
    input: impl Into<ChunkedBatch>,
    window: Option<&ChunkedBatch>,
    model: &DeviceModel,
    backend: ExecBackend,
    runtime: Option<&Runtime>,
    timelines: Option<&mut [GpuTimeline]>,
) -> Result<ClusterOutcome> {
    execute_on_cluster_faulted(
        cluster,
        query,
        plan,
        input,
        window,
        model,
        backend,
        runtime,
        timelines,
        &RoundFaults::default(),
    )
}

/// [`execute_on_cluster_with_occupancy`] under injected faults: an
/// executor listed in `faults.fail` loses its share mid-execution
/// (typed [`Error::Executor`] — the caller's detection/retry path takes
/// over), and an executor listed in `faults.cpu_only` runs its share on
/// the CPU-demoted plan (its GPU device is faulted; row output is
/// unchanged, only the charged physics differ). Fault indices are local
/// to `cluster` — when the caller executes on a survivor subset, it
/// maps physical ids to subset positions first.
#[allow(clippy::too_many_arguments)]
pub fn execute_on_cluster_faulted(
    cluster: &ClusterSpec,
    query: &Query,
    plan: &PhysicalPlan,
    input: impl Into<ChunkedBatch>,
    window: Option<&ChunkedBatch>,
    model: &DeviceModel,
    backend: ExecBackend,
    runtime: Option<&Runtime>,
    timelines: Option<&mut [GpuTimeline]>,
    faults: &RoundFaults,
) -> Result<ClusterOutcome> {
    execute_on_cluster_opts(
        cluster,
        query,
        plan,
        input,
        window,
        model,
        backend,
        runtime,
        timelines,
        faults,
        &ExecOpts::default(),
    )
}

/// [`execute_on_cluster_faulted`] plus [`ExecOpts`]: each executor runs
/// its share through `exec::execute_with_opts`, so fused chains execute
/// as single traversals per share and the encoded window-aux override
/// prices every executor's broadcast build side identically. A
/// GPU-demoted share re-derives its fusion sidecar from the demoted
/// plan — the caller's sidecar describes devices that share no longer
/// uses.
#[allow(clippy::too_many_arguments)]
pub fn execute_on_cluster_opts(
    cluster: &ClusterSpec,
    query: &Query,
    plan: &PhysicalPlan,
    input: impl Into<ChunkedBatch>,
    window: Option<&ChunkedBatch>,
    model: &DeviceModel,
    backend: ExecBackend,
    runtime: Option<&Runtime>,
    mut timelines: Option<&mut [GpuTimeline]>,
    faults: &RoundFaults,
    opts: &ExecOpts,
) -> Result<ClusterOutcome> {
    let input = input.into();
    cluster.validate()?;
    if let Some(tl) = timelines.as_deref() {
        if tl.len() != cluster.executors.len() {
            return Err(Error::Plan(format!(
                "{} GPU timelines for {} executors",
                tl.len(),
                cluster.executors.len()
            )));
        }
    }
    let total_cores = cluster.total_cores();
    let rows = input.rows();

    // Row shares proportional to executor cores (remainder to the last);
    // each share is a chunk-list view — no rows are copied.
    let mut shares = Vec::with_capacity(cluster.executors.len());
    let mut start = 0usize;
    for (i, e) in cluster.executors.iter().enumerate() {
        let len = if i + 1 == cluster.executors.len() {
            rows - start
        } else {
            rows * e.cores / total_cores
        };
        shares.push(input.slice(start, len));
        start += len;
    }

    // Network exchange: every shuffle op moves (E-1)/E of the live data
    // crossing the boundary between executors (hash partitioning sends
    // all but the local fraction).
    let e_count = cluster.executors.len() as f64;
    let cross_fraction = if e_count > 1.0 { (e_count - 1.0) / e_count } else { 0.0 };

    let mut per_executor = Vec::with_capacity(shares.len());
    let mut straggler = Duration::ZERO;
    let mut network = Duration::ZERO;
    for (e, (share, spec)) in shares.into_iter().zip(&cluster.executors).enumerate() {
        if faults.fail.contains(&e) {
            // The executor died (or stalled past the detection timeout)
            // while holding this share: the round's partial work is
            // discarded and the caller re-plans on the survivors.
            return Err(Error::Executor {
                executor: e,
                reason: "lost its share mid-round (injected fault)".into(),
            });
        }
        let env = ExecEnv {
            model,
            backend,
            num_cores: spec.cores,
            num_gpus: spec.gpus,
            runtime,
        };
        let demoted;
        let demoted_fused;
        // Stats never propagate to sliced shares: each executor sees a
        // row-range slice whose chunk list no longer lines up with the
        // staged snapshot's, so inline stats are the correct fallback.
        let mut share_opts = ExecOpts { fused: opts.fused, aux: opts.aux, chunk_stats: None };
        let share_plan = if faults.cpu_only.contains(&e) {
            demoted = plan.demoted_to_cpu();
            if opts.fused.is_some() {
                demoted_fused = fuse::fuse(query, &demoted);
                share_opts.fused = Some(&demoted_fused);
            }
            &demoted
        } else {
            plan
        };
        let mut idle = NoContention;
        let occupancy: &mut dyn exec::GpuOccupancy = match timelines.as_deref_mut() {
            Some(tl) => &mut tl[e],
            None => &mut idle,
        };
        let out = exec::execute_with_opts(
            query, share_plan, share, window, &env, occupancy, &share_opts,
        )?;
        // Charge this executor's shuffle exchanges.
        if e_count > 1.0 {
            for t in &out.traces {
                if t.kind == OpKind::Shuffle {
                    network += cluster
                        .network
                        .transfer(t.in_bytes as f64 * cross_fraction);
                }
            }
        }
        straggler = straggler.max(out.proc);
        per_executor.push(out);
    }

    // Reassembly: O(#chunks) appends per sink, executor order = row
    // order (shares are contiguous row ranges).
    let mut result = ChunkedBatch::new(Arc::clone(per_executor[0].result.schema()));
    for o in &per_executor {
        result.extend(&o.result)?;
    }
    // Branch sinks: every executor ran the same plan, so branch slots
    // align by position; merge each across executors.
    let mut branch_results: Vec<(usize, ChunkedBatch)> = Vec::new();
    for (slot, (op_id, first)) in per_executor[0].branch_results.iter().enumerate() {
        let mut merged = ChunkedBatch::new(Arc::clone(first.schema()));
        for o in &per_executor {
            merged.extend(&o.branch_results[slot].1)?;
        }
        branch_results.push((*op_id, merged));
    }
    let proc = straggler + network + cluster.coordination();
    Ok(ClusterOutcome { result, branch_results, proc, straggler, network, per_executor })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Device;
    use crate::engine::column::ColumnBatch;
    use crate::engine::ops::filter::Predicate;
    use crate::engine::window::WindowSpec;
    use crate::query::builder::QueryBuilder;
    use crate::workloads::linear_road::LinearRoadGen;
    use crate::source::stream::RowGen;

    fn query() -> Query {
        QueryBuilder::scan("cluster-test")
            .window(WindowSpec::sliding(
                Duration::from_secs(30),
                Duration::from_secs(5),
            ))
            .filter("speed", Predicate::Ge(20.0))
            .shuffle("segment")
            .build()
            .unwrap()
    }

    fn input(rows: usize) -> ColumnBatch {
        LinearRoadGen::new(5).generate(0, rows)
    }

    fn run(cluster: &ClusterSpec, rows: usize) -> ClusterOutcome {
        let q = query();
        let plan = PhysicalPlan::uniform(&q, Device::Cpu);
        let model = DeviceModel::default();
        execute_on_cluster(
            cluster,
            &q,
            &plan,
            input(rows),
            None,
            &model,
            ExecBackend::Simulated,
            None,
        )
        .unwrap()
    }

    #[test]
    fn results_identical_across_cluster_sizes() {
        let single = run(&ClusterSpec::single(), 4000);
        let quad = run(&ClusterSpec::paper(), 4000);
        // Shuffle compacts; the surviving row multiset must agree. Our
        // row split preserves order within shards, so compare live rows.
        assert_eq!(single.result.live_rows(), quad.result.live_rows());
    }

    #[test]
    fn scale_out_reduces_straggler_time() {
        let single = run(&ClusterSpec::single(), 40_000);
        let quad = run(&ClusterSpec::paper(), 40_000);
        assert!(
            quad.straggler < single.straggler,
            "4 executors {:?} !< 1 executor {:?}",
            quad.straggler,
            single.straggler
        );
    }

    #[test]
    fn multi_executor_pays_network_on_shuffle() {
        let single = run(&ClusterSpec::single(), 4000);
        let quad = run(&ClusterSpec::paper(), 4000);
        assert_eq!(single.network, Duration::ZERO);
        assert!(quad.network > Duration::ZERO);
    }

    #[test]
    fn coordination_charged_per_batch() {
        let quad = run(&ClusterSpec::paper(), 100);
        assert!(quad.proc >= quad.straggler + ClusterSpec::paper().coordination());
    }

    #[test]
    fn join_window_broadcast_to_all_executors() {
        let q = QueryBuilder::scan("j")
            .window(WindowSpec::sliding(
                Duration::from_secs(30),
                Duration::from_secs(5),
            ))
            .join_window("vehicle", "vehicle")
            .build()
            .unwrap();
        let plan = PhysicalPlan::uniform(&q, Device::Cpu);
        let model = DeviceModel::default();
        let window = ChunkedBatch::from_batch(input(2000));
        let single = execute_on_cluster(
            &ClusterSpec::single(),
            &q,
            &plan,
            input(1000),
            Some(&window),
            &model,
            ExecBackend::Simulated,
            None,
        )
        .unwrap();
        let quad = execute_on_cluster(
            &ClusterSpec::paper(),
            &q,
            &plan,
            input(1000),
            Some(&window),
            &model,
            ExecBackend::Simulated,
            None,
        )
        .unwrap();
        // Join output must be independent of the executor split.
        assert_eq!(single.result.rows(), quad.result.rows());
    }

    #[test]
    fn empty_input_runs() {
        let out = run(&ClusterSpec::paper(), 0);
        assert_eq!(out.result.rows(), 0);
    }

    #[test]
    fn per_executor_timelines_arbitrate_gpu_shares() {
        // A busy per-executor timeline delays that executor's GPU ops;
        // results stay identical to the idle-device run.
        let q = query();
        let plan = PhysicalPlan::uniform(&q, Device::Gpu);
        let model = DeviceModel::default();
        let spec = ClusterSpec::paper();
        let idle = execute_on_cluster(
            &spec, &q, &plan, input(4000), None, &model, ExecBackend::Simulated, None,
        )
        .unwrap();
        let mut timelines: Vec<GpuTimeline> =
            (0..spec.executors.len()).map(|_| GpuTimeline::new()).collect();
        // Pre-book executor 0's GPU for 5 simulated seconds.
        use crate::query::exec::GpuOccupancy;
        timelines[0].request(Duration::ZERO, Duration::from_secs(5));
        let contended = execute_on_cluster_with_occupancy(
            &spec,
            &q,
            &plan,
            input(4000),
            None,
            &model,
            ExecBackend::Simulated,
            None,
            Some(&mut timelines),
        )
        .unwrap();
        assert!(contended.per_executor[0].contention > Duration::ZERO);
        assert_eq!(contended.per_executor[1].contention, Duration::ZERO);
        assert!(contended.straggler > idle.straggler);
        assert_eq!(contended.result, idle.result);
    }

    #[test]
    fn timeline_arity_checked() {
        let q = query();
        let plan = PhysicalPlan::uniform(&q, Device::Cpu);
        let model = DeviceModel::default();
        let mut one = vec![GpuTimeline::new()];
        let r = execute_on_cluster_with_occupancy(
            &ClusterSpec::paper(),
            &q,
            &plan,
            input(10),
            None,
            &model,
            ExecBackend::Simulated,
            None,
            Some(&mut one),
        );
        assert!(r.is_err(), "timeline/executor arity mismatch must error");
    }

    #[test]
    fn reassembly_shares_executor_chunks() {
        // The cluster result aliases the per-executor outputs' chunks —
        // partition reassembly is chunk appends, not a materializing
        // concat.
        let out = run(&ClusterSpec::paper(), 4000);
        assert!(out.result.num_chunks() >= out.per_executor.len());
        let first_exec_chunk = &out.per_executor[0].result.chunks()[0];
        assert!(out.result.chunks()[0].columns[0]
            .shares_memory(&first_exec_chunk.columns[0]));
    }

    #[test]
    fn injected_executor_failure_surfaces_typed_error() {
        let q = query();
        let plan = PhysicalPlan::uniform(&q, Device::Cpu);
        let model = DeviceModel::default();
        let faults = RoundFaults { fail: vec![2], cpu_only: vec![] };
        let r = execute_on_cluster_faulted(
            &ClusterSpec::paper(),
            &q,
            &plan,
            input(4000),
            None,
            &model,
            ExecBackend::Simulated,
            None,
            None,
            &faults,
        );
        match r {
            Err(Error::Executor { executor, .. }) => assert_eq!(executor, 2),
            other => panic!("expected Error::Executor, got {other:?}"),
        }
    }

    #[test]
    fn gpu_demoted_share_keeps_rows_identical() {
        let q = query();
        let plan = PhysicalPlan::uniform(&q, Device::Gpu);
        let model = DeviceModel::default();
        let healthy = execute_on_cluster(
            &ClusterSpec::paper(),
            &q,
            &plan,
            input(4000),
            None,
            &model,
            ExecBackend::Simulated,
            None,
        )
        .unwrap();
        let faults = RoundFaults { fail: vec![], cpu_only: vec![1] };
        let degraded = execute_on_cluster_faulted(
            &ClusterSpec::paper(),
            &q,
            &plan,
            input(4000),
            None,
            &model,
            ExecBackend::Simulated,
            None,
            None,
            &faults,
        )
        .unwrap();
        // Bit-identical output: operators are device-invariant.
        assert_eq!(degraded.result, healthy.result);
        // The demoted executor ran no GPU ops.
        assert_eq!(
            degraded.per_executor[1].traces.iter().filter(|t| t.device == Device::Gpu).count(),
            0
        );
        assert!(degraded
            .per_executor[0]
            .traces
            .iter()
            .any(|t| t.device == Device::Gpu));
    }

    #[test]
    fn branch_sinks_surface_through_cluster() {
        use crate::engine::ops::filter::Predicate as P;
        // scan -> filter -> {select branch sink, select primary sink}.
        let q = QueryBuilder::scan("b")
            .window(WindowSpec::sliding(
                Duration::from_secs(30),
                Duration::from_secs(5),
            ))
            .filter("speed", P::Ge(20.0))
            .branch(|b| b.select(&["vehicle"]))
            .select(&["speed"])
            .build()
            .unwrap();
        let plan = PhysicalPlan::uniform(&q, Device::Cpu);
        let model = DeviceModel::default();
        let out = execute_on_cluster(
            &ClusterSpec::paper(),
            &q,
            &plan,
            input(2000),
            None,
            &model,
            ExecBackend::Simulated,
            None,
        )
        .unwrap();
        assert_eq!(out.branch_results.len(), 1);
        let (op_id, branch) = &out.branch_results[0];
        assert_eq!(*op_id, 2);
        assert_eq!(branch.schema().fields[0].name, "vehicle");
        // Branch rows survive the same filter as the primary sink.
        assert_eq!(branch.live_rows(), out.result.live_rows());
    }
}
