//! Chunked batches: the engine's execution representation.
//!
//! A [`ChunkedBatch`] is an ordered list of `Arc<ColumnBatch>` *chunks*
//! sharing one schema, with cached total and live row counts. It is what
//! every engine operator consumes and produces (see `engine::ops` and
//! `devices::{cpu,gpu}`), so the places that used to materialize a
//! multi-part [`ColumnBatch::concat`] — `Union` input assembly in the
//! executor, cluster partition reassembly, and the window snapshot ∪
//! new-input union — become O(#chunks) Arc appends with **zero row
//! copies**.
//!
//! # Invariants
//!
//! * Every chunk's schema content-equals the batch schema (checked on
//!   [`ChunkedBatch::push`] / [`ChunkedBatch::extend`]).
//! * Chunks are immutable (shared `Arc`s); a retained `ChunkedBatch`
//!   clone is never affected by later appends elsewhere — there is no
//!   copy-on-write anywhere on this path.
//! * Zero-row chunks are permitted; `rows()`/`live_rows()` are cached,
//!   O(1).
//! * Logical content is the in-order concatenation of the chunks:
//!   [`ChunkedBatch::coalesce`] materializes it, and `PartialEq`
//!   compares it — two layouts of the same rows are equal.
//!
//! # Coalesce points
//!
//! Ops that genuinely need contiguity call an explicit coalesce, whose
//! cost the planner and device model charge:
//!
//! * `sort` (global order over all rows),
//! * real-GPU kernels at a host→device boundary (PJRT wants contiguous
//!   staging buffers; see [`crate::devices::gpu::run_op_chunked`] and
//!   `DeviceModel::coalesce_time`),
//! * validation sinks ([`crate::engine::sink::CollectSink`]).
//!
//! Everything else (filter, project, expand, scan, aggregate, join
//! probe, shuffle) iterates the chunk list directly; the differential
//! harness (`rust/tests/diff_chunked.rs`) pins that chunked execution is
//! bit-identical to coalesced single-chunk execution.

use crate::engine::column::{ColumnBatch, Schema};
use crate::error::{Error, Result};
use std::sync::Arc;

/// An ordered list of schema-sharing column-batch chunks; see the
/// module docs for the invariants.
#[derive(Clone, Debug)]
pub struct ChunkedBatch {
    schema: Arc<Schema>,
    chunks: Vec<Arc<ColumnBatch>>,
    /// Cached total rows (live + dead) across chunks.
    rows: usize,
    /// Cached live rows across chunks.
    live: usize,
}

impl ChunkedBatch {
    /// Empty batch (no chunks) of `schema`.
    pub fn new(schema: Arc<Schema>) -> ChunkedBatch {
        ChunkedBatch { schema, chunks: Vec::new(), rows: 0, live: 0 }
    }

    /// Single-chunk batch wrapping `batch` (no row copies).
    pub fn from_batch(batch: ColumnBatch) -> ChunkedBatch {
        ChunkedBatch::from_arc(Arc::new(batch))
    }

    /// Single-chunk batch sharing an already-Arc'd chunk — O(1).
    pub fn from_arc(batch: Arc<ColumnBatch>) -> ChunkedBatch {
        let rows = batch.rows();
        let live = batch.live_rows();
        let schema = Arc::clone(&batch.schema);
        ChunkedBatch { schema, chunks: vec![batch], rows, live }
    }

    /// Assemble from a chunk list; every chunk must match `schema`.
    pub fn from_chunks(
        schema: Arc<Schema>,
        chunks: Vec<Arc<ColumnBatch>>,
    ) -> Result<ChunkedBatch> {
        let mut out = ChunkedBatch::new(schema);
        for c in chunks {
            out.push_arc(c)?;
        }
        Ok(out)
    }

    pub fn schema(&self) -> &Arc<Schema> {
        &self.schema
    }

    pub fn chunks(&self) -> &[Arc<ColumnBatch>] {
        &self.chunks
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Total rows (live + dead) — O(1), cached.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Live rows — O(1), cached.
    pub fn live_rows(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Allocated view bytes across chunks (what kernels/PCIe move; the
    /// cost model and admission charge this, as for [`ColumnBatch`]).
    pub fn alloc_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.alloc_bytes()).sum()
    }

    /// Live-row bytes across chunks (post-compaction footprint).
    pub fn live_bytes(&self) -> usize {
        self.chunks.iter().map(|c| c.live_bytes()).sum()
    }

    /// Append one chunk — O(1) beyond the schema check.
    pub fn push(&mut self, chunk: ColumnBatch) -> Result<()> {
        self.push_arc(Arc::new(chunk))
    }

    /// Append one shared chunk — O(1) beyond the schema check.
    pub fn push_arc(&mut self, chunk: Arc<ColumnBatch>) -> Result<()> {
        if *chunk.schema != *self.schema {
            return Err(Error::Schema("concat over mixed schemas".into()));
        }
        self.rows += chunk.rows();
        self.live += chunk.live_rows();
        self.chunks.push(chunk);
        Ok(())
    }

    /// Append every chunk of `other` — O(#chunks) Arc bumps, no copies.
    pub fn extend(&mut self, other: &ChunkedBatch) -> Result<()> {
        if *other.schema != *self.schema {
            return Err(Error::Schema("concat over mixed schemas".into()));
        }
        self.rows += other.rows;
        self.live += other.live;
        self.chunks.extend(other.chunks.iter().cloned());
        Ok(())
    }

    /// Concatenate chunked batches — O(total #chunks) Arc appends: this
    /// is the `Union` / reassembly path that used to materialize.
    pub fn concat(parts: &[&ChunkedBatch]) -> Result<ChunkedBatch> {
        let first = parts.first().ok_or_else(|| Error::Schema("empty concat".into()))?;
        let mut out = ChunkedBatch::new(Arc::clone(&first.schema));
        for p in parts {
            out.extend(p)?;
        }
        Ok(out)
    }

    /// Materialize the in-order concatenation as one contiguous batch —
    /// the explicit coalesce point. A single chunk is an O(1) clone; an
    /// empty chunk list yields an empty batch of the schema.
    pub fn coalesce(&self) -> ColumnBatch {
        match self.chunks.len() {
            0 => ColumnBatch::empty(Arc::clone(&self.schema)),
            1 => (*self.chunks[0]).clone(),
            _ => {
                let refs: Vec<&ColumnBatch> =
                    self.chunks.iter().map(|c| c.as_ref()).collect();
                ColumnBatch::concat(&refs).expect("chunks share one schema")
            }
        }
    }

    /// [`ChunkedBatch::coalesce`] behind an `Arc`; a single chunk is
    /// shared, not cloned.
    pub fn coalesce_arc(&self) -> Arc<ColumnBatch> {
        if self.chunks.len() == 1 {
            Arc::clone(&self.chunks[0])
        } else {
            Arc::new(self.coalesce())
        }
    }

    /// Contiguous row range `[start, start+len)` as a chunk-list view:
    /// fully covered chunks are shared (O(1) Arc bumps), at most the two
    /// edge chunks are sliced (themselves O(#columns) buffer views).
    pub fn slice(&self, start: usize, len: usize) -> ChunkedBatch {
        assert!(
            start + len <= self.rows,
            "slice [{start}, {start}+{len}) of {}",
            self.rows
        );
        let mut out = ChunkedBatch::new(Arc::clone(&self.schema));
        let mut skip = start;
        let mut need = len;
        for c in &self.chunks {
            if need == 0 {
                break;
            }
            let r = c.rows();
            if skip >= r {
                skip -= r;
                continue;
            }
            let take = (r - skip).min(need);
            if skip == 0 && take == r {
                out.push_arc(Arc::clone(c)).expect("chunk schemas are uniform");
            } else {
                out.push(c.slice(skip, take)).expect("chunk schemas are uniform");
            }
            skip = 0;
            need -= take;
        }
        debug_assert_eq!(out.rows, len);
        out
    }
}

impl From<ColumnBatch> for ChunkedBatch {
    fn from(b: ColumnBatch) -> ChunkedBatch {
        ChunkedBatch::from_batch(b)
    }
}

impl From<Arc<ColumnBatch>> for ChunkedBatch {
    fn from(b: Arc<ColumnBatch>) -> ChunkedBatch {
        ChunkedBatch::from_arc(b)
    }
}

impl PartialEq for ChunkedBatch {
    /// Layout-independent logical equality: same schema and the same
    /// in-order rows (values + liveness), whatever the chunking.
    fn eq(&self, other: &ChunkedBatch) -> bool {
        *self.schema == *other.schema
            && self.rows == other.rows
            && self.live == other.live
            && self.coalesce() == other.coalesce()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, Field};

    fn batch(vals: &[f32]) -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("x")]);
        ColumnBatch::new(schema, vec![Column::F32(vals.to_vec().into())]).unwrap()
    }

    #[test]
    fn caches_row_and_live_counts() {
        let mut c = ChunkedBatch::from_batch(batch(&[1.0, 2.0]));
        let mut dead = batch(&[3.0, 4.0, 5.0]);
        dead.validity.set_live(0, false);
        c.push(dead).unwrap();
        assert_eq!(c.num_chunks(), 2);
        assert_eq!(c.rows(), 5);
        assert_eq!(c.live_rows(), 4);
    }

    #[test]
    fn schema_mismatch_rejected() {
        let mut c = ChunkedBatch::from_batch(batch(&[1.0]));
        let other = ColumnBatch::new(
            Schema::new(vec![Field::f32("y")]),
            vec![Column::F32(vec![1.0].into())],
        )
        .unwrap();
        assert!(c.push(other).is_err());
    }

    #[test]
    fn coalesce_is_in_order_concat() {
        let mut c = ChunkedBatch::from_batch(batch(&[1.0, 2.0]));
        c.push(batch(&[3.0])).unwrap();
        let whole = c.coalesce();
        assert_eq!(whole.column("x").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn single_chunk_coalesce_shares_memory() {
        let b = batch(&[1.0, 2.0]);
        let c = ChunkedBatch::from_batch(b.clone());
        let w = c.coalesce();
        assert!(b.columns[0].shares_memory(&w.columns[0]));
        let arc1 = c.coalesce_arc();
        let arc2 = c.coalesce_arc();
        assert!(Arc::ptr_eq(&arc1, &arc2));
    }

    #[test]
    fn empty_chunk_list_coalesces_to_empty_batch() {
        let c = ChunkedBatch::new(Schema::new(vec![Field::f32("x")]));
        assert!(c.is_empty());
        let w = c.coalesce();
        assert_eq!(w.rows(), 0);
        assert_eq!(w.schema.len(), 1);
    }

    #[test]
    fn concat_is_chunk_appends_not_copies() {
        let a = ChunkedBatch::from_batch(batch(&[1.0]));
        let b = ChunkedBatch::from_batch(batch(&[2.0, 3.0]));
        let u = ChunkedBatch::concat(&[&a, &b]).unwrap();
        assert_eq!(u.num_chunks(), 2);
        assert_eq!(u.rows(), 3);
        // The union's chunks alias the inputs' chunk allocations.
        assert!(u.chunks()[0].columns[0].shares_memory(&a.chunks()[0].columns[0]));
        assert!(u.chunks()[1].columns[0].shares_memory(&b.chunks()[0].columns[0]));
    }

    #[test]
    fn slice_crosses_chunk_boundaries() {
        let mut c = ChunkedBatch::from_batch(batch(&[0.0, 1.0, 2.0]));
        c.push(batch(&[3.0, 4.0])).unwrap();
        c.push(batch(&[5.0, 6.0, 7.0])).unwrap();
        let s = c.slice(2, 4);
        assert_eq!(s.rows(), 4);
        assert_eq!(
            s.coalesce().column("x").unwrap().as_f32().unwrap(),
            &[2.0, 3.0, 4.0, 5.0]
        );
        // The fully covered middle chunk is shared, not sliced.
        assert!(s.chunks()[1].columns[0].shares_memory(&c.chunks()[1].columns[0]));
        assert_eq!(s.chunks()[1].rows(), 2);
    }

    #[test]
    fn slice_empty_range() {
        let c = ChunkedBatch::from_batch(batch(&[1.0, 2.0]));
        let s = c.slice(1, 0);
        assert_eq!(s.rows(), 0);
        assert_eq!(s.num_chunks(), 0);
    }

    #[test]
    fn equality_is_layout_independent() {
        let whole = ChunkedBatch::from_batch(batch(&[1.0, 2.0, 3.0]));
        let mut split = ChunkedBatch::from_batch(batch(&[1.0]));
        split.push(batch(&[2.0, 3.0])).unwrap();
        assert_eq!(whole, split);
        let different = ChunkedBatch::from_batch(batch(&[1.0, 2.0, 4.0]));
        assert_ne!(whole, different);
    }

    #[test]
    fn retained_clone_unaffected_by_later_pushes() {
        let mut c = ChunkedBatch::from_batch(batch(&[1.0]));
        let held = c.clone();
        c.push(batch(&[2.0])).unwrap();
        assert_eq!(held.rows(), 1);
        assert_eq!(c.rows(), 2);
        assert_eq!(held.coalesce().column("x").unwrap().as_f32().unwrap(), &[1.0]);
    }
}
