//! Output sinks: where micro-batch results leave the system ("goes out
//! to the output stream", §V-B).
//!
//! The [`Sink`] trait receives each batch's result rows — in the
//! engine's chunked representation, so pass-through results reach the
//! sink without a materializing concat — with completion time;
//! implementations collect rows for validation ([`CollectSink`], which
//! coalesces: validation wants one contiguous batch and is an explicit
//! coalesce point), count/summarize ([`CountingSink`]), or drop
//! ([`NullSink`]).

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::ColumnBatch;
use crate::error::Result;
use crate::sim::Time;

/// Receives query results batch by batch.
pub trait Sink: Send {
    /// Deliver one micro-batch result. `completed_at` is the processing
    /// completion time (output-stream timestamp).
    fn deliver(&mut self, batch_index: usize, result: &ChunkedBatch, completed_at: Time)
        -> Result<()>;
}

/// Drops results (benchmark default).
#[derive(Default)]
pub struct NullSink;

impl Sink for NullSink {
    fn deliver(&mut self, _i: usize, _r: &ChunkedBatch, _t: Time) -> Result<()> {
        Ok(())
    }
}

/// Counts delivered rows/batches (O(#chunks) per delivery — no coalesce).
#[derive(Default, Debug)]
pub struct CountingSink {
    pub batches: usize,
    pub rows: usize,
    pub live_rows: usize,
    pub bytes: usize,
    pub last_completed_at: Time,
}

impl Sink for CountingSink {
    fn deliver(&mut self, _i: usize, result: &ChunkedBatch, t: Time) -> Result<()> {
        self.batches += 1;
        self.rows += result.rows();
        self.live_rows += result.live_rows();
        self.bytes += result.alloc_bytes();
        self.last_completed_at = self.last_completed_at.max(t);
        Ok(())
    }
}

/// Retains full results for validation (bounded by `max_batches` to keep
/// long runs from hoarding memory). Coalesces on delivery — an explicit
/// coalesce point (O(1) for the common single-chunk aggregate results).
pub struct CollectSink {
    pub results: Vec<(usize, Time, ColumnBatch)>,
    max_batches: usize,
}

impl CollectSink {
    pub fn new(max_batches: usize) -> CollectSink {
        CollectSink { results: Vec::new(), max_batches }
    }
}

impl Sink for CollectSink {
    fn deliver(&mut self, i: usize, result: &ChunkedBatch, t: Time) -> Result<()> {
        if self.results.len() < self.max_batches {
            self.results.push((i, t, result.coalesce()));
        }
        Ok(())
    }
}

/// Exactly-once wrapper: forwards each batch index to the inner sink at
/// most once, in index order. Batch indices are per-query monotone (the
/// checkpoint restores counts across incarnations), so a single
/// high-water mark is a complete dedup record — the same gate the
/// session's durable [`SinkLedger`](crate::durability::SinkLedger)
/// applies before owned sinks are even reached; `DedupSink` lets
/// externally-owned sinks enforce the contract locally too.
pub struct DedupSink<S: Sink> {
    inner: S,
    /// Highest index delivered, if any (index 0 delivered ≠ nothing).
    high_water: Option<usize>,
}

impl<S: Sink> DedupSink<S> {
    pub fn new(inner: S) -> DedupSink<S> {
        DedupSink { inner, high_water: None }
    }

    /// Highest batch index forwarded to the inner sink so far.
    pub fn delivered_high_water(&self) -> Option<usize> {
        self.high_water
    }

    /// The wrapped sink (inspect collected/counted state).
    pub fn inner(&self) -> &S {
        &self.inner
    }
}

impl<S: Sink> Sink for DedupSink<S> {
    fn deliver(&mut self, i: usize, result: &ChunkedBatch, t: Time) -> Result<()> {
        if self.high_water.is_some_and(|hw| i <= hw) {
            return Ok(()); // replayed duplicate: suppress
        }
        self.inner.deliver(i, result, t)?;
        self.high_water = Some(i);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, Field, Schema};

    fn batch(rows: usize) -> ChunkedBatch {
        let schema = Schema::new(vec![Field::f32("x")]);
        ChunkedBatch::from_batch(
            ColumnBatch::new(schema, vec![Column::F32(vec![1.0; rows].into())]).unwrap(),
        )
    }

    #[test]
    fn counting_sink_accumulates() {
        let mut s = CountingSink::default();
        s.deliver(0, &batch(5), Time::from_secs_f64(1.0)).unwrap();
        s.deliver(1, &batch(7), Time::from_secs_f64(2.0)).unwrap();
        assert_eq!(s.batches, 2);
        assert_eq!(s.rows, 12);
        assert_eq!(s.last_completed_at, Time::from_secs_f64(2.0));
    }

    #[test]
    fn counting_sink_sums_across_chunks() {
        let mut multi = batch(3);
        multi.push(batch(4).coalesce()).unwrap();
        let mut s = CountingSink::default();
        s.deliver(0, &multi, Time::ZERO).unwrap();
        assert_eq!(s.rows, 7);
        assert_eq!(s.live_rows, 7);
    }

    #[test]
    fn collect_sink_bounded() {
        let mut s = CollectSink::new(2);
        for i in 0..5 {
            s.deliver(i, &batch(1), Time::ZERO).unwrap();
        }
        assert_eq!(s.results.len(), 2);
    }

    #[test]
    fn collect_sink_coalesces_chunked_results() {
        let mut multi = batch(2);
        multi.push(batch(3).coalesce()).unwrap();
        let mut s = CollectSink::new(4);
        s.deliver(0, &multi, Time::ZERO).unwrap();
        assert_eq!(s.results[0].2.rows(), 5);
    }

    #[test]
    fn null_sink_accepts_everything() {
        let mut s = NullSink;
        s.deliver(0, &batch(100), Time::ZERO).unwrap();
    }

    #[test]
    fn dedup_sink_suppresses_replayed_indices() {
        let mut s = DedupSink::new(CountingSink::default());
        s.deliver(0, &batch(2), Time::ZERO).unwrap();
        s.deliver(1, &batch(3), Time::ZERO).unwrap();
        // Replay from the start: both already delivered.
        s.deliver(0, &batch(2), Time::ZERO).unwrap();
        s.deliver(1, &batch(3), Time::ZERO).unwrap();
        // Fresh index passes through.
        s.deliver(2, &batch(5), Time::ZERO).unwrap();
        assert_eq!(s.inner().batches, 3);
        assert_eq!(s.inner().rows, 10);
        assert_eq!(s.delivered_high_water(), Some(2));
    }

    #[test]
    fn dedup_sink_index_zero_is_delivered_state() {
        let mut s = DedupSink::new(CountingSink::default());
        assert_eq!(s.delivered_high_water(), None);
        s.deliver(0, &batch(1), Time::ZERO).unwrap();
        s.deliver(0, &batch(1), Time::ZERO).unwrap();
        assert_eq!(s.inner().batches, 1);
        assert_eq!(s.delivered_high_water(), Some(0));
    }
}
