//! Window specifications and state.
//!
//! The paper keys its admission bound on the window type: `SlideTime > 0`
//! means a sliding window (bound = slide time, Eq. 2); `SlideTime == 0`
//! denotes a tumbling window (bound = running average of past
//! max-latencies, Eq. 3). Window *state* holds the recent datasets a
//! windowed operator (self-join / windowed aggregate) computes over.
//!
//! # Incremental snapshot
//!
//! The build side a windowed operator reads every micro-batch used to be
//! re-concatenated from scratch — O(window rows) of copying per batch.
//! [`WindowState`] now maintains a [`SnapshotCache`]: per-column append
//! buffers that grow by O(delta) on [`WindowState::push`] (via
//! `Arc::make_mut`, copy-on-write only if a previous snapshot is still
//! alive) and shrink by an O(1) offset bump on [`WindowState::evict`]
//! (the dead prefix is compacted away only once it exceeds the live
//! region, keeping memory bounded at 2x and amortized cost O(1)/row).
//! [`WindowState::snapshot`] then hands out an `Arc<ColumnBatch>` whose
//! columns are O(1) views into the cache — per-batch snapshot cost is
//! O(#columns + delta), not O(window).

use crate::engine::column::{Buffer, Column, ColumnBatch, Schema, Validity};
use crate::engine::dataset::Dataset;
use crate::error::{Error, Result};
use crate::sim::Time;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

/// Window shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowKind {
    Sliding,
    Tumbling,
}

/// `[range R (slide S)]` of Table III.
#[derive(Clone, Copy, Debug)]
pub struct WindowSpec {
    pub range: Duration,
    /// Paper convention: zero slide ⇒ tumbling window.
    pub slide: Duration,
}

impl WindowSpec {
    pub fn sliding(range: Duration, slide: Duration) -> WindowSpec {
        assert!(!slide.is_zero(), "sliding window needs slide > 0");
        WindowSpec { range, slide }
    }

    pub fn tumbling(range: Duration) -> WindowSpec {
        WindowSpec { range, slide: Duration::ZERO }
    }

    pub fn kind(&self) -> WindowKind {
        if self.slide.is_zero() {
            WindowKind::Tumbling
        } else {
            WindowKind::Sliding
        }
    }

    /// `SlideTime` of Table I (0 for tumbling).
    pub fn slide_time(&self) -> Duration {
        self.slide
    }

    /// Work multiplier of the Spark `Expand` rewrite for sliding windows:
    /// each row belongs to ceil(range/slide) overlapping window instances.
    pub fn expand_factor(&self) -> f64 {
        match self.kind() {
            WindowKind::Tumbling => 1.0,
            WindowKind::Sliding => {
                (self.range.as_secs_f64() / self.slide.as_secs_f64()).ceil().max(1.0)
            }
        }
    }
}

/// One append buffer of the snapshot cache (parallel to the schema).
#[derive(Debug)]
enum AccumCol {
    F32(Arc<Vec<f32>>),
    I32(Arc<Vec<i32>>),
}

/// Incrementally maintained concatenation of the in-window datasets.
/// `[start, start+len)` of every buffer is the current window; rows in
/// front of `start` were evicted and await compaction.
#[derive(Debug)]
struct SnapshotCache {
    schema: Arc<Schema>,
    cols: Vec<AccumCol>,
    /// Row mask aligned with `cols`; `None` while every appended dataset
    /// was fully live (the common case — nothing allocated).
    mask: Option<Arc<Vec<u8>>>,
    /// Dead (evicted) prefix rows.
    start: usize,
    /// Rows currently in the window.
    len: usize,
    /// Live rows within `[start, start+len)` (kept incrementally so the
    /// snapshot's validity needs no recount).
    live: usize,
    /// Total buffer rows (= start + len; tracked explicitly so an empty
    /// schema cannot desync it).
    buf_rows: usize,
}

impl SnapshotCache {
    fn new(schema: Arc<Schema>) -> SnapshotCache {
        let cols = schema
            .fields
            .iter()
            .map(|f| match f.dtype {
                crate::engine::column::DType::F32 => AccumCol::F32(Arc::new(Vec::new())),
                crate::engine::column::DType::I32 => AccumCol::I32(Arc::new(Vec::new())),
            })
            .collect();
        SnapshotCache { schema, cols, mask: None, start: 0, len: 0, live: 0, buf_rows: 0 }
    }

    /// Append one dataset's rows; O(rows appended), copy-on-write only if
    /// an old snapshot still aliases the buffers. Returns `false` on a
    /// schema mismatch (caller drops the cache and falls back to a full
    /// rebuild, which surfaces the error).
    fn append(&mut self, batch: &ColumnBatch) -> bool {
        if *batch.schema != *self.schema {
            return false;
        }
        let rows = batch.rows();
        // Mask maintenance: materialize lazily on the first dataset that
        // carries dead rows.
        if let Some(inc) = batch.validity.mask() {
            if self.mask.is_none() {
                self.mask = Some(Arc::new(vec![1u8; self.buf_rows]));
            }
            Arc::make_mut(self.mask.as_mut().expect("just ensured"))
                .extend_from_slice(inc);
        } else if let Some(m) = &mut self.mask {
            let v = Arc::make_mut(m);
            v.resize(v.len() + rows, 1);
        }
        for (acc, col) in self.cols.iter_mut().zip(&batch.columns) {
            match (acc, col) {
                (AccumCol::F32(b), Column::F32(v)) => {
                    Arc::make_mut(b).extend_from_slice(v.as_slice())
                }
                (AccumCol::I32(b), Column::I32(v)) => {
                    Arc::make_mut(b).extend_from_slice(v.as_slice())
                }
                // Unreachable after the schema check; bail so the caller
                // rebuilds rather than serving a corrupt cache.
                _ => return false,
            }
        }
        self.buf_rows += rows;
        self.len += rows;
        self.live += batch.live_rows();
        true
    }

    /// Drop `rows` evicted rows (with `live` of them live) off the front —
    /// an O(1) offset bump, compacting only when the dead prefix exceeds
    /// the live region.
    fn trim_front(&mut self, rows: usize, live: usize) {
        debug_assert!(rows <= self.len && live <= self.live);
        self.start += rows;
        self.len -= rows;
        self.live -= live;
    }

    fn maybe_compact(&mut self) {
        if self.start == 0 || self.start < self.len {
            return;
        }
        let (s, l) = (self.start, self.len);
        for acc in &mut self.cols {
            match acc {
                AccumCol::F32(b) => *b = Arc::new(b[s..s + l].to_vec()),
                AccumCol::I32(b) => *b = Arc::new(b[s..s + l].to_vec()),
            }
        }
        if let Some(m) = &mut self.mask {
            *m = Arc::new(m[s..s + l].to_vec());
        }
        self.start = 0;
        self.buf_rows = l;
    }

    /// Assemble the snapshot batch: O(#columns) Arc clones, zero row
    /// copies.
    fn assemble(&self) -> ColumnBatch {
        let columns = self
            .cols
            .iter()
            .map(|acc| match acc {
                AccumCol::F32(b) => {
                    Column::F32(Buffer::view(Arc::clone(b), self.start, self.len))
                }
                AccumCol::I32(b) => {
                    Column::I32(Buffer::view(Arc::clone(b), self.start, self.len))
                }
            })
            .collect();
        let validity = match &self.mask {
            None => Validity::all_live(self.len),
            Some(m) => Validity::from_parts(
                Buffer::view(Arc::clone(m), self.start, self.len),
                self.live,
            ),
        };
        ColumnBatch { schema: Arc::clone(&self.schema), columns, validity }
    }
}

/// Retained stream history for windowed operators (the `SegSpeedStr as A`
/// side of LR1's self-join; the aggregation scope of LR2S/CM*).
#[derive(Debug, Default)]
pub struct WindowState {
    entries: VecDeque<Dataset>,
    /// Incremental build-side concatenation (rebuilt lazily when absent).
    cache: Option<SnapshotCache>,
    /// Memoized assembled snapshot; invalidated by push/evict.
    snap: Option<Arc<ColumnBatch>>,
}

impl WindowState {
    pub fn new() -> WindowState {
        WindowState::default()
    }

    /// Datasets currently in state.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total rows in state.
    pub fn rows(&self) -> usize {
        self.entries.iter().map(|d| d.rows()).sum()
    }

    /// Total wire bytes in state (sizing windowed-operator cost).
    pub fn wire_bytes(&self) -> usize {
        self.entries.iter().map(|d| d.wire_bytes).sum()
    }

    /// Insert processed datasets into state: O(delta) appends into the
    /// snapshot cache (dataset clones are O(#columns) Arc bumps).
    pub fn push(&mut self, datasets: &[Dataset]) {
        if datasets.is_empty() {
            return;
        }
        self.snap = None;
        for d in datasets {
            if let Some(c) = &mut self.cache {
                if !c.append(&d.batch) {
                    // Schema drift: drop the cache; snapshot() rebuilds
                    // (and reports mixed schemas, as concat used to).
                    self.cache = None;
                }
            }
            self.entries.push_back(d.clone());
        }
    }

    /// Evict datasets whose event time has fallen out of `[now - range, now]`.
    pub fn evict(&mut self, now: Time, spec: &WindowSpec) {
        let horizon = Time(now.0.saturating_sub(spec.range.as_nanos() as u64));
        let mut evicted = false;
        while let Some(front) = self.entries.front() {
            if front.event_time < horizon {
                let d = self.entries.pop_front().expect("front exists");
                if let Some(c) = &mut self.cache {
                    c.trim_front(d.rows(), d.batch.live_rows());
                }
                evicted = true;
            } else {
                break;
            }
        }
        if evicted {
            self.snap = None;
            if self.entries.is_empty() {
                self.cache = None;
            } else if let Some(c) = &mut self.cache {
                c.maybe_compact();
            }
        }
    }

    /// Snapshot of all in-window rows as one shared batch (build side of
    /// joins / aggregation scope). `None` when state is empty. Amortized
    /// O(#columns) per call: rows were already appended into the cache by
    /// `push`; only the first call after a cold start (or schema drift)
    /// pays a full O(window) rebuild.
    pub fn snapshot(&mut self) -> Result<Option<Arc<ColumnBatch>>> {
        if self.entries.is_empty() {
            return Ok(None);
        }
        if let Some(s) = &self.snap {
            return Ok(Some(Arc::clone(s)));
        }
        if self.cache.is_none() {
            self.rebuild_cache()?;
        }
        let snap = Arc::new(self.cache.as_ref().expect("just built").assemble());
        self.snap = Some(Arc::clone(&snap));
        Ok(Some(snap))
    }

    /// Reference implementation: concatenate every in-window dataset from
    /// scratch — O(window rows). Kept for equivalence tests and as the
    /// baseline the `perf_hotpath` bench compares the incremental path
    /// against.
    pub fn snapshot_fresh(&self) -> Result<Option<ColumnBatch>> {
        if self.entries.is_empty() {
            return Ok(None);
        }
        let parts: Vec<&ColumnBatch> = self.entries.iter().map(|d| &d.batch).collect();
        Ok(Some(ColumnBatch::concat(&parts)?))
    }

    /// Test hook: `(dead-prefix rows, total buffer rows)` of the
    /// snapshot cache, `None` while no cache is built. Pins the
    /// compaction memory bound (`buf_rows <= 2 * live region`).
    #[cfg(test)]
    fn cache_geometry(&self) -> Option<(usize, usize)> {
        self.cache.as_ref().map(|c| (c.start, c.buf_rows))
    }

    fn rebuild_cache(&mut self) -> Result<()> {
        let first = self.entries.front().expect("rebuild over non-empty state");
        let mut cache = SnapshotCache::new(Arc::clone(&first.batch.schema));
        for d in &self.entries {
            if !cache.append(&d.batch) {
                return Err(Error::Schema(
                    "window state holds datasets with mixed schemas".into(),
                ));
            }
        }
        self.cache = Some(cache);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn ds(id: u64, t: f64) -> Dataset {
        let schema = Schema::new(vec![Field::f32("x")]);
        Dataset {
            id,
            created_at: Time::from_secs_f64(t),
            event_time: Time::from_secs_f64(t),
            batch: ColumnBatch::new(
                schema,
                vec![Column::F32(vec![t as f32; 5].into())],
            )
            .unwrap(),
            wire_bytes: 5 * 65,
        }
    }

    #[test]
    fn window_kind_from_slide() {
        let s = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        assert_eq!(s.kind(), WindowKind::Sliding);
        let t = WindowSpec::tumbling(Duration::from_secs(30));
        assert_eq!(t.kind(), WindowKind::Tumbling);
        assert_eq!(t.slide_time(), Duration::ZERO);
    }

    #[test]
    fn expand_factor_matches_range_over_slide() {
        let s = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        assert_eq!(s.expand_factor(), 6.0);
        let t = WindowSpec::tumbling(Duration::from_secs(60));
        assert_eq!(t.expand_factor(), 1.0);
    }

    #[test]
    #[should_panic(expected = "slide > 0")]
    fn sliding_rejects_zero_slide() {
        WindowSpec::sliding(Duration::from_secs(30), Duration::ZERO);
    }

    #[test]
    fn eviction_respects_range() {
        let spec = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        let mut w = WindowState::new();
        w.push(&[ds(0, 0.0), ds(1, 20.0), ds(2, 40.0)]);
        assert_eq!(w.rows(), 15);
        w.evict(Time::from_secs_f64(45.0), &spec);
        // horizon = 15s: dataset at t=0 leaves, t=20 and t=40 stay.
        assert_eq!(w.len(), 2);
        assert_eq!(w.rows(), 10);
    }

    #[test]
    fn snapshot_concatenates_state() {
        let mut w = WindowState::new();
        assert!(w.snapshot().unwrap().is_none());
        w.push(&[ds(0, 1.0), ds(1, 2.0)]);
        let snap = w.snapshot().unwrap().unwrap();
        assert_eq!(snap.rows(), 10);
    }

    #[test]
    fn wire_bytes_tracks_state() {
        let mut w = WindowState::new();
        w.push(&[ds(0, 1.0)]);
        assert_eq!(w.wire_bytes(), 5 * 65);
    }

    #[test]
    fn incremental_snapshot_equals_fresh_concat() {
        let spec = WindowSpec::sliding(Duration::from_secs(10), Duration::from_secs(2));
        let mut w = WindowState::new();
        let mut t = 0.0;
        for step in 0..40u64 {
            t += 0.7 * ((step % 3) as f64 + 1.0);
            w.evict(Time::from_secs_f64(t), &spec);
            w.push(&[ds(step, t)]);
            let inc = w.snapshot().unwrap().unwrap();
            let fresh = w.snapshot_fresh().unwrap().unwrap();
            assert_eq!(*inc, fresh, "step {step}: snapshot diverged");
        }
    }

    #[test]
    fn snapshot_memoized_until_state_changes() {
        let mut w = WindowState::new();
        w.push(&[ds(0, 1.0)]);
        let a = w.snapshot().unwrap().unwrap();
        let b = w.snapshot().unwrap().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "unchanged state must reuse the snapshot");
        w.push(&[ds(1, 2.0)]);
        let c = w.snapshot().unwrap().unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.rows(), 10);
    }

    #[test]
    fn outstanding_snapshot_survives_push_and_evict() {
        // Copy-on-write: holding a snapshot across mutations must not
        // change what it sees.
        let spec = WindowSpec::sliding(Duration::from_secs(5), Duration::from_secs(1));
        let mut w = WindowState::new();
        w.push(&[ds(0, 0.0), ds(1, 1.0)]);
        let held = w.snapshot().unwrap().unwrap();
        let before = held.column("x").unwrap().as_f32().unwrap().to_vec();
        w.push(&[ds(2, 2.0)]);
        w.evict(Time::from_secs_f64(7.0), &spec);
        let _new = w.snapshot().unwrap().unwrap();
        assert_eq!(held.column("x").unwrap().as_f32().unwrap(), &before[..]);
        assert_eq!(held.rows(), 10);
    }

    #[test]
    fn eviction_compacts_dead_prefix_eventually() {
        let spec = WindowSpec::sliding(Duration::from_secs(3), Duration::from_secs(1));
        let mut w = WindowState::new();
        let mut t = 0.0;
        for step in 0..200u64 {
            t += 1.0;
            w.evict(Time::from_secs_f64(t), &spec);
            w.push(&[ds(step, t)]);
            let snap = w.snapshot().unwrap().unwrap();
            let fresh = w.snapshot_fresh().unwrap().unwrap();
            assert_eq!(*snap, fresh, "step {step}");
            // Window is 3-4 datasets; the cache must not grow unboundedly.
            assert!(w.len() <= 4, "window kept {} datasets", w.len());
            // Compaction bound: the accumulation buffers never exceed 2x
            // the live region (dead prefix is trimmed once it outgrows it).
            let (start, buf_rows) =
                w.cache_geometry().expect("cache built by snapshot");
            let live_region = w.rows();
            assert!(
                start <= live_region && buf_rows <= 2 * live_region.max(1),
                "step {step}: cache grew unboundedly \
                 (start {start}, buf {buf_rows}, live region {live_region})"
            );
        }
    }
}
