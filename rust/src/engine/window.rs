//! Window specifications and state.
//!
//! The paper keys its admission bound on the window type: `SlideTime > 0`
//! means a sliding window (bound = slide time, Eq. 2); `SlideTime == 0`
//! denotes a tumbling window (bound = running average of past
//! max-latencies, Eq. 3). Window *state* holds the recent datasets a
//! windowed operator (self-join / windowed aggregate) computes over.

use crate::engine::column::ColumnBatch;
use crate::engine::dataset::Dataset;
use crate::error::Result;
use crate::sim::Time;
use std::collections::VecDeque;
use std::time::Duration;

/// Window shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowKind {
    Sliding,
    Tumbling,
}

/// `[range R (slide S)]` of Table III.
#[derive(Clone, Copy, Debug)]
pub struct WindowSpec {
    pub range: Duration,
    /// Paper convention: zero slide ⇒ tumbling window.
    pub slide: Duration,
}

impl WindowSpec {
    pub fn sliding(range: Duration, slide: Duration) -> WindowSpec {
        assert!(!slide.is_zero(), "sliding window needs slide > 0");
        WindowSpec { range, slide }
    }

    pub fn tumbling(range: Duration) -> WindowSpec {
        WindowSpec { range, slide: Duration::ZERO }
    }

    pub fn kind(&self) -> WindowKind {
        if self.slide.is_zero() {
            WindowKind::Tumbling
        } else {
            WindowKind::Sliding
        }
    }

    /// `SlideTime` of Table I (0 for tumbling).
    pub fn slide_time(&self) -> Duration {
        self.slide
    }

    /// Work multiplier of the Spark `Expand` rewrite for sliding windows:
    /// each row belongs to ceil(range/slide) overlapping window instances.
    pub fn expand_factor(&self) -> f64 {
        match self.kind() {
            WindowKind::Tumbling => 1.0,
            WindowKind::Sliding => {
                (self.range.as_secs_f64() / self.slide.as_secs_f64()).ceil().max(1.0)
            }
        }
    }
}

/// Retained stream history for windowed operators (the `SegSpeedStr as A`
/// side of LR1's self-join; the aggregation scope of LR2S/CM*).
#[derive(Debug, Default)]
pub struct WindowState {
    entries: VecDeque<Dataset>,
}

impl WindowState {
    pub fn new() -> WindowState {
        WindowState::default()
    }

    /// Datasets currently in state.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total rows in state.
    pub fn rows(&self) -> usize {
        self.entries.iter().map(|d| d.rows()).sum()
    }

    /// Total wire bytes in state (sizing windowed-operator cost).
    pub fn wire_bytes(&self) -> usize {
        self.entries.iter().map(|d| d.wire_bytes).sum()
    }

    /// Insert processed datasets into state.
    pub fn push(&mut self, datasets: &[Dataset]) {
        for d in datasets {
            self.entries.push_back(d.clone());
        }
    }

    /// Evict datasets whose event time has fallen out of `[now - range, now]`.
    pub fn evict(&mut self, now: Time, spec: &WindowSpec) {
        let horizon = Time(now.0.saturating_sub(spec.range.as_nanos() as u64));
        while let Some(front) = self.entries.front() {
            if front.event_time < horizon {
                self.entries.pop_front();
            } else {
                break;
            }
        }
    }

    /// Snapshot of all in-window rows as one batch (build side of joins /
    /// aggregation scope). `None` when state is empty.
    pub fn snapshot(&self) -> Result<Option<ColumnBatch>> {
        if self.entries.is_empty() {
            return Ok(None);
        }
        let parts: Vec<&ColumnBatch> = self.entries.iter().map(|d| &d.batch).collect();
        Ok(Some(ColumnBatch::concat(&parts)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn ds(id: u64, t: f64) -> Dataset {
        let schema = Schema::new(vec![Field::f32("x")]);
        Dataset {
            id,
            created_at: Time::from_secs_f64(t),
            event_time: Time::from_secs_f64(t),
            batch: ColumnBatch::new(schema, vec![Column::F32(vec![t as f32; 5])])
                .unwrap(),
            wire_bytes: 5 * 65,
        }
    }

    #[test]
    fn window_kind_from_slide() {
        let s = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        assert_eq!(s.kind(), WindowKind::Sliding);
        let t = WindowSpec::tumbling(Duration::from_secs(30));
        assert_eq!(t.kind(), WindowKind::Tumbling);
        assert_eq!(t.slide_time(), Duration::ZERO);
    }

    #[test]
    fn expand_factor_matches_range_over_slide() {
        let s = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        assert_eq!(s.expand_factor(), 6.0);
        let t = WindowSpec::tumbling(Duration::from_secs(60));
        assert_eq!(t.expand_factor(), 1.0);
    }

    #[test]
    #[should_panic(expected = "slide > 0")]
    fn sliding_rejects_zero_slide() {
        WindowSpec::sliding(Duration::from_secs(30), Duration::ZERO);
    }

    #[test]
    fn eviction_respects_range() {
        let spec = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        let mut w = WindowState::new();
        w.push(&[ds(0, 0.0), ds(1, 20.0), ds(2, 40.0)]);
        assert_eq!(w.rows(), 15);
        w.evict(Time::from_secs_f64(45.0), &spec);
        // horizon = 15s: dataset at t=0 leaves, t=20 and t=40 stay.
        assert_eq!(w.len(), 2);
        assert_eq!(w.rows(), 10);
    }

    #[test]
    fn snapshot_concatenates_state() {
        let mut w = WindowState::new();
        assert!(w.snapshot().unwrap().is_none());
        w.push(&[ds(0, 1.0), ds(1, 2.0)]);
        let snap = w.snapshot().unwrap().unwrap();
        assert_eq!(snap.rows(), 10);
    }

    #[test]
    fn wire_bytes_tracks_state() {
        let mut w = WindowState::new();
        w.push(&[ds(0, 1.0)]);
        assert_eq!(w.wire_bytes(), 5 * 65);
    }
}
