//! Window specifications and state.
//!
//! The paper keys its admission bound on the window type: `SlideTime > 0`
//! means a sliding window (bound = slide time, Eq. 2); `SlideTime == 0`
//! denotes a tumbling window (bound = running average of past
//! max-latencies, Eq. 3). Window *state* holds the recent datasets a
//! windowed operator (self-join / windowed aggregate) computes over.
//!
//! # Chunked snapshot
//!
//! The build side a windowed operator reads every micro-batch used to be
//! re-concatenated from scratch — O(window rows) of copying per batch —
//! and, after PR 2, maintained in per-column append buffers whose
//! copy-on-write still cost one O(window) copy whenever a sink retained
//! an old snapshot. The state ∪ new-input union is now a
//! [`ChunkedBatch`]: one shared `Arc<ColumnBatch>` chunk per in-window
//! dataset. [`WindowState::push`] appends chunks (O(#columns) Arc wraps
//! per dataset), [`WindowState::evict`] pops them (O(1) per dataset),
//! and [`WindowState::snapshot_chunks`] assembles the chunk-list view in
//! O(#datasets) Arc bumps — zero row copies, and **no copy-on-write at
//! all**: chunks are immutable, so a snapshot held across pushes/evicts
//! keeps exactly what it captured for free.
//!
//! [`WindowState::snapshot`] (the memoized *contiguous* snapshot) and
//! [`WindowState::snapshot_fresh`] remain as the coalesced reference
//! implementations the equivalence tests and benches compare against.
//!
//! # Hot/cold encoded state
//!
//! A long window keeps most of its chunks untouched between snapshots:
//! only the recent tail changes as datasets push in. State is therefore
//! split at [`WINDOW_HOT_CHUNKS`]: the newest chunks stay *hot* (plain
//! `Arc<ColumnBatch>`, zero-cost snapshot), while every chunk that falls
//! past the threshold is demoted to *cold* — re-encoded as an
//! [`EncodedChunk`] (RLE / dictionary / delta per column, min/max stats
//! attached) and the plain form dropped. Cold chunks decode **lazily**
//! on the first snapshot that needs them, memoized until eviction; the
//! decode cache is excluded from [`WindowState::state_bytes_encoded`]
//! because it is droppable at any time. Snapshots are bit-identical
//! either way (codecs are exact, f32 preserved by bit pattern — see
//! [`crate::engine::encode`]), which `diff_chunked` pins under arbitrary
//! push/evict interleavings.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::ColumnBatch;
use crate::engine::dataset::Dataset;
use crate::engine::encode::{encode_chunk, ChunkStats, EncodedChunk};
use crate::error::{Error, Result};
use crate::sim::Time;
use std::collections::VecDeque;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// How many of the newest window chunks stay hot (plain, un-encoded).
/// Chunks demote to encoded cold form when a push leaves them more than
/// this many positions from the tail.
pub const WINDOW_HOT_CHUNKS: usize = 8;

/// Window shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowKind {
    Sliding,
    Tumbling,
}

/// `[range R (slide S)]` of Table III.
#[derive(Clone, Copy, Debug)]
pub struct WindowSpec {
    pub range: Duration,
    /// Paper convention: zero slide ⇒ tumbling window.
    pub slide: Duration,
}

impl WindowSpec {
    pub fn sliding(range: Duration, slide: Duration) -> WindowSpec {
        assert!(!slide.is_zero(), "sliding window needs slide > 0");
        WindowSpec { range, slide }
    }

    pub fn tumbling(range: Duration) -> WindowSpec {
        WindowSpec { range, slide: Duration::ZERO }
    }

    pub fn kind(&self) -> WindowKind {
        if self.slide.is_zero() {
            WindowKind::Tumbling
        } else {
            WindowKind::Sliding
        }
    }

    /// `SlideTime` of Table I (0 for tumbling).
    pub fn slide_time(&self) -> Duration {
        self.slide
    }

    /// Work multiplier of the Spark `Expand` rewrite for sliding windows:
    /// each row belongs to ceil(range/slide) overlapping window instances.
    pub fn expand_factor(&self) -> f64 {
        match self.kind() {
            WindowKind::Tumbling => 1.0,
            WindowKind::Sliding => {
                (self.range.as_secs_f64() / self.slide.as_secs_f64()).ceil().max(1.0)
            }
        }
    }
}

/// Per-dataset bookkeeping the state keeps alongside each chunk. The
/// dataset's *batch* is not retained here — the chunk slot owns the only
/// reference, so demoting a slot to cold genuinely frees the raw buffers
/// (nothing else pins them).
#[derive(Clone, Copy, Debug)]
struct EntryMeta {
    id: u64,
    event_time: Time,
    rows: usize,
    wire_bytes: usize,
}

/// A cold (encoded) chunk plus its lazily-memoized decode. The decode
/// cache is a pure accelerator: dropping it loses nothing.
#[derive(Debug)]
struct ColdChunk {
    encoded: EncodedChunk,
    decoded: OnceLock<Arc<ColumnBatch>>,
}

impl ColdChunk {
    fn batch(&self) -> Arc<ColumnBatch> {
        Arc::clone(
            self.decoded
                .get_or_init(|| Arc::new(self.encoded.decode())),
        )
    }
}

/// One window chunk: hot (plain) for the recent tail, cold (encoded)
/// past [`WINDOW_HOT_CHUNKS`].
#[derive(Debug)]
enum StateChunk {
    Hot(Arc<ColumnBatch>),
    Cold(ColdChunk),
}

impl StateChunk {
    /// The plain chunk view (decoding and memoizing a cold slot on
    /// first use).
    fn batch(&self) -> Arc<ColumnBatch> {
        match self {
            StateChunk::Hot(c) => Arc::clone(c),
            StateChunk::Cold(c) => c.batch(),
        }
    }

    /// Bytes this slot would occupy fully decoded.
    fn raw_bytes(&self) -> usize {
        match self {
            StateChunk::Hot(c) => c.alloc_bytes(),
            StateChunk::Cold(c) => c.encoded.raw_bytes(),
        }
    }

    /// Bytes this slot actually holds (decode cache excluded — it is
    /// droppable).
    fn encoded_bytes(&self) -> usize {
        match self {
            StateChunk::Hot(c) => c.alloc_bytes(),
            StateChunk::Cold(c) => c.encoded.encoded_bytes(),
        }
    }
}

/// Retained stream history for windowed operators (the `SegSpeedStr as A`
/// side of LR1's self-join; the aggregation scope of LR2S/CM*).
#[derive(Debug, Default)]
pub struct WindowState {
    /// Per-dataset metadata, ordered by `(event_time, id)`.
    entries: VecDeque<EntryMeta>,
    /// One slot per entry (same order): the building blocks of
    /// [`WindowState::snapshot_chunks`]. Hot slots are immutable shared
    /// chunks, so held snapshots never see later mutations — no
    /// copy-on-write exists; cold slots decode to a memoized chunk.
    chunks: VecDeque<StateChunk>,
    /// Memoized *contiguous* snapshot; invalidated by push/evict.
    snap: Option<Arc<ColumnBatch>>,
}

impl WindowState {
    pub fn new() -> WindowState {
        WindowState::default()
    }

    /// Datasets currently in state.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total rows in state.
    pub fn rows(&self) -> usize {
        self.entries.iter().map(|e| e.rows).sum()
    }

    /// Total wire bytes in state (sizing windowed-operator cost).
    pub fn wire_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.wire_bytes).sum()
    }

    /// Bytes the state would occupy with every chunk held plain.
    pub fn state_bytes_raw(&self) -> usize {
        self.chunks.iter().map(|c| c.raw_bytes()).sum()
    }

    /// Bytes the state actually holds: hot chunks at their plain
    /// allocation, cold chunks at their encoded footprint (the lazy
    /// decode cache is excluded — it is droppable). Never exceeds
    /// [`WindowState::state_bytes_raw`]: the encoder keeps a column
    /// plain (shared, not copied) when no codec wins.
    pub fn state_bytes_encoded(&self) -> usize {
        self.chunks.iter().map(|c| c.encoded_bytes()).sum()
    }

    /// Number of cold (encoded) chunks currently in state.
    pub fn cold_chunks(&self) -> usize {
        self.chunks
            .iter()
            .filter(|c| matches!(c, StateChunk::Cold(_)))
            .count()
    }

    /// Insert processed datasets into state, kept ordered by
    /// `(event_time, id)`: for in-order input every insert is a pure
    /// O(#columns) Arc-wrapped chunk append (no row copies, the
    /// historical fast path); an out-of-order dataset files into its
    /// event position so the state — and therefore any snapshot — is an
    /// arrival-permutation-invariant function of the event stream.
    pub fn push(&mut self, datasets: &[Dataset]) {
        if datasets.is_empty() {
            return;
        }
        self.snap = None;
        for d in datasets {
            let key = (d.event_time, d.id);
            let pos = self
                .entries
                .iter()
                .rposition(|e| (e.event_time, e.id) <= key)
                .map(|p| p + 1)
                .unwrap_or(0);
            let meta = EntryMeta {
                id: d.id,
                event_time: d.event_time,
                rows: d.rows(),
                wire_bytes: d.wire_bytes,
            };
            let slot = StateChunk::Hot(Arc::new(d.batch.clone()));
            if pos == self.entries.len() {
                self.chunks.push_back(slot);
                self.entries.push_back(meta);
            } else {
                self.chunks.insert(pos, slot);
                self.entries.insert(pos, meta);
            }
        }
        self.demote_cold();
    }

    /// Demote every hot chunk that has fallen more than
    /// [`WINDOW_HOT_CHUNKS`] positions from the tail: encode it and drop
    /// the plain form (the slot holds the only reference, so the raw
    /// buffers are freed — unless a caller still holds an older
    /// snapshot, which keeps exactly what it captured). Demotion is
    /// one-way: a cold chunk re-entering the hot region (out-of-order
    /// insert behind it) stays cold and simply decodes lazily.
    fn demote_cold(&mut self) {
        let cold_end = self.chunks.len().saturating_sub(WINDOW_HOT_CHUNKS);
        for slot in self.chunks.iter_mut().take(cold_end) {
            if let StateChunk::Hot(c) = slot {
                *slot = StateChunk::Cold(ColdChunk {
                    encoded: encode_chunk(c),
                    decoded: OnceLock::new(),
                });
            }
        }
    }

    /// Evict datasets whose event time has fallen out of `[now - range, now]`
    /// — an O(1) chunk pop per evicted dataset.
    pub fn evict(&mut self, now: Time, spec: &WindowSpec) {
        let horizon = Time(now.0.saturating_sub(spec.range.as_nanos() as u64));
        let mut evicted = false;
        while let Some(front) = self.entries.front() {
            if front.event_time < horizon {
                self.entries.pop_front();
                self.chunks.pop_front();
                evicted = true;
            } else {
                break;
            }
        }
        if evicted {
            self.snap = None;
        }
    }

    /// The state ∪ window view as a chunk list — the execution input /
    /// join build side [`crate::session::Session`] consumes. `None` when
    /// state is empty. O(#datasets) Arc bumps, zero row copies, and a
    /// held snapshot is never perturbed by later push/evict (chunks are
    /// immutable). Errors if the state holds mixed schemas.
    pub fn snapshot_chunks(&self) -> Result<Option<ChunkedBatch>> {
        let first = match self.chunks.front() {
            None => return Ok(None),
            Some(c) => c.batch(),
        };
        let mut out = ChunkedBatch::new(Arc::clone(&first.schema));
        for c in &self.chunks {
            out.push_arc(c.batch()).map_err(|_| {
                Error::Schema("window state holds datasets with mixed schemas".into())
            })?;
        }
        Ok(Some(out))
    }

    /// Encode-time min/max stats for each chunk of
    /// [`WindowState::snapshot_chunks`]'s view, index-aligned with it:
    /// `Some` for cold chunks (whose [`EncodedChunk`] already carries
    /// per-column bounds from encoding), `None` for hot ones (stats
    /// were never taken — fused pruning computes them inline as
    /// before). Lets aggregate-tail fused chains skip the per-chunk
    /// stats recomputation for the cold bulk of a long window.
    pub fn snapshot_chunk_stats(&self) -> Vec<Option<ChunkStats>> {
        self.chunks
            .iter()
            .map(|c| match c {
                StateChunk::Hot(_) => None,
                StateChunk::Cold(cold) => Some(cold.encoded.stats()),
            })
            .collect()
    }

    /// The prefix of state at or before an event-time boundary, as a
    /// chunk list (`None` when nothing qualifies). Entries are
    /// event-ordered, so the view is a prefix — O(#datasets) Arc bumps
    /// like [`WindowState::snapshot_chunks`]. The boundary is
    /// *inclusive*, mirroring the eviction horizon's convention: a
    /// window closing at watermark `w` computes over every event `<= w`
    /// still in range. This is what makes watermark-driven window-close
    /// arrival-permutation-invariant: any late-but-allowed dataset has
    /// filed into its event position before the prefix is taken.
    pub fn snapshot_up_to(&self, boundary: Time) -> Result<Option<ChunkedBatch>> {
        let first = match (self.entries.front(), self.chunks.front()) {
            (Some(e), Some(c)) if e.event_time <= boundary => c.batch(),
            _ => return Ok(None),
        };
        let mut out = ChunkedBatch::new(Arc::clone(&first.schema));
        for (e, c) in self.entries.iter().zip(self.chunks.iter()) {
            if e.event_time > boundary {
                break;
            }
            out.push_arc(c.batch()).map_err(|_| {
                Error::Schema("window state holds datasets with mixed schemas".into())
            })?;
        }
        Ok(Some(out))
    }

    /// Memoized *contiguous* snapshot (coalesced chunk list): the
    /// reference/compat form for callers that need one `ColumnBatch`.
    /// A single-dataset window shares the chunk (O(1)); otherwise the
    /// first call after a state change pays the one O(window) coalesce.
    pub fn snapshot(&mut self) -> Result<Option<Arc<ColumnBatch>>> {
        if self.entries.is_empty() {
            return Ok(None);
        }
        if let Some(s) = &self.snap {
            return Ok(Some(Arc::clone(s)));
        }
        let chunked = self.snapshot_chunks()?.expect("non-empty state");
        let snap = chunked.coalesce_arc();
        self.snap = Some(Arc::clone(&snap));
        Ok(Some(snap))
    }

    /// Reference implementation: concatenate every in-window dataset from
    /// scratch — O(window rows). Kept for equivalence tests and as the
    /// baseline the `perf_hotpath` bench compares the chunked path
    /// against.
    pub fn snapshot_fresh(&self) -> Result<Option<ColumnBatch>> {
        if self.entries.is_empty() {
            return Ok(None);
        }
        let batches: Vec<Arc<ColumnBatch>> =
            self.chunks.iter().map(|c| c.batch()).collect();
        let parts: Vec<&ColumnBatch> = batches.iter().map(|b| b.as_ref()).collect();
        Ok(Some(ColumnBatch::concat(&parts)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn ds(id: u64, t: f64) -> Dataset {
        let schema = Schema::new(vec![Field::f32("x")]);
        Dataset {
            id,
            created_at: Time::from_secs_f64(t),
            event_time: Time::from_secs_f64(t),
            batch: ColumnBatch::new(
                schema,
                vec![Column::F32(vec![t as f32; 5].into())],
            )
            .unwrap(),
            wire_bytes: 5 * 65,
        }
    }

    #[test]
    fn window_kind_from_slide() {
        let s = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        assert_eq!(s.kind(), WindowKind::Sliding);
        let t = WindowSpec::tumbling(Duration::from_secs(30));
        assert_eq!(t.kind(), WindowKind::Tumbling);
        assert_eq!(t.slide_time(), Duration::ZERO);
    }

    #[test]
    fn expand_factor_matches_range_over_slide() {
        let s = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        assert_eq!(s.expand_factor(), 6.0);
        let t = WindowSpec::tumbling(Duration::from_secs(60));
        assert_eq!(t.expand_factor(), 1.0);
    }

    #[test]
    #[should_panic(expected = "slide > 0")]
    fn sliding_rejects_zero_slide() {
        WindowSpec::sliding(Duration::from_secs(30), Duration::ZERO);
    }

    #[test]
    fn eviction_respects_range() {
        let spec = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        let mut w = WindowState::new();
        w.push(&[ds(0, 0.0), ds(1, 20.0), ds(2, 40.0)]);
        assert_eq!(w.rows(), 15);
        w.evict(Time::from_secs_f64(45.0), &spec);
        // horizon = 15s: dataset at t=0 leaves, t=20 and t=40 stay.
        assert_eq!(w.len(), 2);
        assert_eq!(w.rows(), 10);
    }

    #[test]
    fn snapshot_concatenates_state() {
        let mut w = WindowState::new();
        assert!(w.snapshot().unwrap().is_none());
        w.push(&[ds(0, 1.0), ds(1, 2.0)]);
        let snap = w.snapshot().unwrap().unwrap();
        assert_eq!(snap.rows(), 10);
    }

    #[test]
    fn wire_bytes_tracks_state() {
        let mut w = WindowState::new();
        w.push(&[ds(0, 1.0)]);
        assert_eq!(w.wire_bytes(), 5 * 65);
    }

    #[test]
    fn incremental_snapshot_equals_fresh_concat() {
        let spec = WindowSpec::sliding(Duration::from_secs(10), Duration::from_secs(2));
        let mut w = WindowState::new();
        let mut t = 0.0;
        for step in 0..40u64 {
            t += 0.7 * ((step % 3) as f64 + 1.0);
            w.evict(Time::from_secs_f64(t), &spec);
            w.push(&[ds(step, t)]);
            let inc = w.snapshot().unwrap().unwrap();
            let fresh = w.snapshot_fresh().unwrap().unwrap();
            assert_eq!(*inc, fresh, "step {step}: snapshot diverged");
        }
    }

    #[test]
    fn snapshot_memoized_until_state_changes() {
        let mut w = WindowState::new();
        w.push(&[ds(0, 1.0)]);
        let a = w.snapshot().unwrap().unwrap();
        let b = w.snapshot().unwrap().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "unchanged state must reuse the snapshot");
        w.push(&[ds(1, 2.0)]);
        let c = w.snapshot().unwrap().unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.rows(), 10);
    }

    #[test]
    fn outstanding_snapshot_survives_push_and_evict() {
        // Copy-on-write: holding a snapshot across mutations must not
        // change what it sees.
        let spec = WindowSpec::sliding(Duration::from_secs(5), Duration::from_secs(1));
        let mut w = WindowState::new();
        w.push(&[ds(0, 0.0), ds(1, 1.0)]);
        let held = w.snapshot().unwrap().unwrap();
        let before = held.column("x").unwrap().as_f32().unwrap().to_vec();
        w.push(&[ds(2, 2.0)]);
        w.evict(Time::from_secs_f64(7.0), &spec);
        let _new = w.snapshot().unwrap().unwrap();
        assert_eq!(held.column("x").unwrap().as_f32().unwrap(), &before[..]);
        assert_eq!(held.rows(), 10);
    }

    #[test]
    fn long_runs_keep_memory_bounded_to_the_window() {
        let spec = WindowSpec::sliding(Duration::from_secs(3), Duration::from_secs(1));
        let mut w = WindowState::new();
        let mut t = 0.0;
        for step in 0..200u64 {
            t += 1.0;
            w.evict(Time::from_secs_f64(t), &spec);
            w.push(&[ds(step, t)]);
            let snap = w.snapshot().unwrap().unwrap();
            let fresh = w.snapshot_fresh().unwrap().unwrap();
            assert_eq!(*snap, fresh, "step {step}");
            // Window is 3-4 datasets; chunk count tracks it exactly —
            // evicted chunks are dropped immediately, so state memory is
            // bounded by the in-window rows (no dead prefix exists).
            assert!(w.len() <= 4, "window kept {} datasets", w.len());
            let chunked = w.snapshot_chunks().unwrap().unwrap();
            assert_eq!(chunked.num_chunks(), w.len(), "step {step}");
            assert_eq!(chunked.rows(), w.rows(), "step {step}");
        }
    }

    #[test]
    fn chunked_snapshot_shares_dataset_buffers() {
        let mut w = WindowState::new();
        let d = ds(0, 1.0);
        w.push(&[d.clone(), ds(1, 2.0)]);
        let chunked = w.snapshot_chunks().unwrap().unwrap();
        assert_eq!(chunked.num_chunks(), 2);
        assert_eq!(chunked.rows(), 10);
        // Chunk 0 aliases the pushed dataset's buffers: zero row copies.
        assert!(chunked.chunks()[0].columns[0].shares_memory(&d.batch.columns[0]));
    }

    #[test]
    fn held_chunked_snapshot_unaffected_by_push_and_evict() {
        // The CoW caveat is gone: chunks are immutable, so a held
        // snapshot needs no copy-on-write to stay stable.
        let spec = WindowSpec::sliding(Duration::from_secs(5), Duration::from_secs(1));
        let mut w = WindowState::new();
        w.push(&[ds(0, 0.0), ds(1, 1.0)]);
        let held = w.snapshot_chunks().unwrap().unwrap();
        let before = held.coalesce();
        w.push(&[ds(2, 2.0)]);
        w.evict(Time::from_secs_f64(7.0), &spec);
        assert_eq!(held.rows(), 10);
        assert_eq!(held.coalesce(), before);
    }

    /// Dataset with decoupled event/arrival times.
    fn ds_at(id: u64, event: f64, arrival: f64) -> Dataset {
        let mut d = ds(id, event);
        d.created_at = Time::from_secs_f64(arrival);
        d
    }

    #[test]
    fn eviction_boundary_is_inclusive() {
        // Satellite: a dataset exactly at `now - range` is retained.
        let spec = WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5));
        let mut w = WindowState::new();
        w.push(&[ds(0, 10.0), ds(1, 15.0), ds(2, 40.0)]);
        w.evict(Time::from_secs_f64(40.0), &spec); // horizon = exactly 10s
        assert_eq!(w.len(), 3, "dataset at now - range must survive eviction");
        // One nanosecond past the boundary evicts it.
        w.evict(Time(Time::from_secs_f64(40.0).0 + 1), &spec);
        assert_eq!(w.len(), 2);
        assert_eq!(w.rows(), 10);
    }

    #[test]
    fn eviction_boundary_identical_under_contiguous_and_chunked_snapshots() {
        let spec = WindowSpec::tumbling(Duration::from_secs(20));
        let build = || {
            let mut w = WindowState::new();
            w.push(&[ds(0, 5.0), ds(1, 25.0), ds(2, 26.0)]);
            w.evict(Time::from_secs_f64(25.0), &spec); // horizon = exactly 5s
            w
        };
        let mut a = build();
        let b = build();
        let contiguous = a.snapshot().unwrap().unwrap();
        let chunked = b.snapshot_chunks().unwrap().unwrap();
        assert_eq!(a.len(), 3, "boundary dataset retained");
        assert_eq!(chunked.coalesce(), *contiguous);
        assert_eq!(chunked.rows(), 15);
    }

    #[test]
    fn eviction_boundary_unchanged_when_watermark_driven() {
        // Evicting by a watermark (max event − lateness) goes through the
        // same `evict(now, spec)` entry point: the inclusive-horizon
        // semantics must not depend on where the time came from, and a
        // late-but-allowed dataset filed behind the boundary is evicted
        // by exactly the same rule.
        let spec = WindowSpec::sliding(Duration::from_secs(10), Duration::from_secs(2));
        let mut by_clock = WindowState::new();
        let mut by_watermark = WindowState::new();
        // In-order state for the clock; the watermark state receives the
        // same datasets with the middle one arriving late (out of order).
        by_clock.push(&[ds(0, 8.0), ds(1, 12.0), ds(2, 18.0)]);
        by_watermark.push(&[ds_at(0, 8.0, 8.0), ds_at(2, 18.0, 18.0)]);
        by_watermark.push(&[ds_at(1, 12.0, 18.5)]); // late arrival, files in
        let max_event = Time::from_secs_f64(18.0);
        let lateness = Duration::from_secs(0);
        let watermark = Time(max_event.0 - lateness.as_nanos() as u64);
        by_clock.evict(max_event, &spec);
        by_watermark.evict(watermark, &spec);
        // horizon = exactly 8s: the boundary dataset survives in both.
        assert_eq!(by_clock.len(), 3);
        assert_eq!(by_watermark.len(), 3);
        let a = by_clock.snapshot_chunks().unwrap().unwrap();
        let b = by_watermark.snapshot_chunks().unwrap().unwrap();
        assert_eq!(a.coalesce(), b.coalesce(), "watermark eviction diverged");
    }

    #[test]
    fn out_of_order_push_files_into_event_position() {
        let mut in_order = WindowState::new();
        in_order.push(&[ds(0, 1.0), ds(1, 2.0), ds(2, 3.0), ds(3, 4.0)]);
        let mut permuted = WindowState::new();
        permuted.push(&[ds_at(2, 3.0, 3.0)]);
        permuted.push(&[ds_at(0, 1.0, 3.2), ds_at(3, 4.0, 4.0)]);
        permuted.push(&[ds_at(1, 2.0, 4.5)]);
        let a = in_order.snapshot_chunks().unwrap().unwrap();
        let b = permuted.snapshot_chunks().unwrap().unwrap();
        assert_eq!(a.coalesce(), b.coalesce(), "event order not restored");
        assert_eq!(b.num_chunks(), 4);
    }

    #[test]
    fn snapshot_up_to_takes_inclusive_event_prefix() {
        let mut w = WindowState::new();
        w.push(&[ds(0, 1.0), ds(1, 2.0), ds(2, 3.0)]);
        assert!(w.snapshot_up_to(Time::from_secs_f64(0.5)).unwrap().is_none());
        let p = w.snapshot_up_to(Time::from_secs_f64(2.0)).unwrap().unwrap();
        assert_eq!(p.num_chunks(), 2, "boundary event included");
        assert_eq!(p.rows(), 10);
        let all = w.snapshot_up_to(Time::from_secs_f64(99.0)).unwrap().unwrap();
        assert_eq!(all.coalesce(), w.snapshot_chunks().unwrap().unwrap().coalesce());
    }

    #[test]
    fn chunked_and_contiguous_snapshots_agree() {
        let mut w = WindowState::new();
        w.push(&[ds(0, 1.0), ds(1, 2.0), ds(2, 3.0)]);
        let chunked = w.snapshot_chunks().unwrap().unwrap();
        let contiguous = w.snapshot().unwrap().unwrap();
        assert_eq!(chunked.coalesce(), *contiguous);
    }

    #[test]
    fn chunks_demote_past_hot_threshold_and_shrink() {
        let mut w = WindowState::new();
        for i in 0..12u64 {
            w.push(&[ds(i, i as f64 + 1.0)]);
        }
        assert_eq!(w.len(), 12);
        assert_eq!(
            w.cold_chunks(),
            12 - WINDOW_HOT_CHUNKS,
            "everything past the hot tail demotes"
        );
        // Each 5-row constant chunk: raw 4*5 + 5 = 25 bytes, RLE 8 + 5 = 13.
        assert_eq!(w.state_bytes_raw(), 12 * 25);
        assert_eq!(
            w.state_bytes_encoded(),
            (12 - WINDOW_HOT_CHUNKS) * 13 + WINDOW_HOT_CHUNKS * 25
        );
        assert!(w.state_bytes_encoded() < w.state_bytes_raw());
    }

    #[test]
    fn cold_snapshot_is_bit_identical_to_pushed_data() {
        let mut w = WindowState::new();
        for i in 0..12u64 {
            w.push(&[ds(i, i as f64 + 1.0)]);
        }
        assert!(w.cold_chunks() > 0);
        let snap = w.snapshot_chunks().unwrap().unwrap().coalesce();
        let expected: Vec<u32> = (0..12)
            .flat_map(|i| std::iter::repeat(((i + 1) as f32).to_bits()).take(5))
            .collect();
        let got: Vec<u32> = snap
            .column("x")
            .unwrap()
            .as_f32()
            .unwrap()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        assert_eq!(got, expected, "cold decode must reproduce exact bits");
        let fresh = w.snapshot_fresh().unwrap().unwrap();
        assert_eq!(snap, fresh);
    }

    #[test]
    fn cold_decode_is_memoized_across_snapshots() {
        let mut w = WindowState::new();
        for i in 0..12u64 {
            w.push(&[ds(i, i as f64 + 1.0)]);
        }
        let a = w.snapshot_chunks().unwrap().unwrap();
        let b = w.snapshot_chunks().unwrap().unwrap();
        // Chunk 0 is cold: both snapshots must share the one decode.
        assert!(
            Arc::ptr_eq(&a.chunks()[0], &b.chunks()[0]),
            "cold chunk decoded twice"
        );
    }

    #[test]
    fn out_of_order_insert_behind_cold_region_stays_consistent() {
        let mut in_order = WindowState::new();
        let mut late = WindowState::new();
        in_order.push(&[ds_at(0, 0.5, 0.5)]);
        for i in 1..=11u64 {
            let d = ds(i, i as f64);
            in_order.push(&[d.clone()]);
            late.push(&[d]);
        }
        // A late dataset files in front of already-cold chunks.
        late.push(&[ds_at(0, 0.5, 12.0)]);
        let a = in_order.snapshot_chunks().unwrap().unwrap();
        let b = late.snapshot_chunks().unwrap().unwrap();
        assert_eq!(a.coalesce(), b.coalesce(), "cold region broke event ordering");
        assert_eq!(b.coalesce(), late.snapshot_fresh().unwrap().unwrap());
    }
}
