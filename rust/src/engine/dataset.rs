//! Datasets (the paper's arrival unit — one "file" / row-record group per
//! ingest tick) and micro-batches (the execution unit, `NumDS_i` datasets).

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::ColumnBatch;
use crate::error::{Error, Result};
use crate::sim::Time;
use std::sync::Arc;

/// One ingested dataset: rows that arrived together, stamped with their
/// creation time (the paper's file creation time; latency is measured from
/// here — end-to-end, §V-B).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Monotone ingest sequence number.
    pub id: u64,
    /// Creation/arrival time.
    pub created_at: Time,
    /// Event time of the rows: the logical tick the generator produced
    /// them for (`tick_no × tick duration`), decoupled from arrival —
    /// under [`crate::source::stream::Disorder`] a dataset can arrive
    /// after younger events. Equal to `created_at` for in-order streams.
    pub event_time: Time,
    /// Row data.
    pub batch: ColumnBatch,
    /// Wire size in bytes (CSV-equivalent; this is the `Part`/size measure
    /// the paper's cost models use, not our in-memory footprint).
    pub wire_bytes: usize,
}

impl Dataset {
    pub fn rows(&self) -> usize {
        self.batch.rows()
    }
}

/// A micro-batch: the datasets admitted for one processing-phase execution.
#[derive(Clone, Debug, Default)]
pub struct MicroBatch {
    pub datasets: Vec<Dataset>,
}

impl MicroBatch {
    pub fn new(datasets: Vec<Dataset>) -> MicroBatch {
        MicroBatch { datasets }
    }

    /// `NumDS_i` in Table I.
    pub fn num_datasets(&self) -> usize {
        self.datasets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.datasets.is_empty()
    }

    pub fn rows(&self) -> usize {
        self.datasets.iter().map(|d| d.rows()).sum()
    }

    /// Total wire bytes (Σ_j Part_(i,j) numerator of Eq. 4).
    pub fn wire_bytes(&self) -> usize {
        self.datasets.iter().map(|d| d.wire_bytes).sum()
    }

    /// Earliest dataset creation time — the row that has buffered longest
    /// (max_j Buff in Eqs. 5/6 is measured against this).
    pub fn oldest_created_at(&self) -> Option<Time> {
        self.datasets.iter().map(|d| d.created_at).min()
    }

    /// Newest event time (window head).
    pub fn newest_event_time(&self) -> Option<Time> {
        self.datasets.iter().map(|d| d.event_time).max()
    }

    /// All rows concatenated into one batch (O(1) — a shared view — when
    /// the micro-batch holds a single dataset).
    pub fn concat(&self) -> Result<ColumnBatch> {
        let parts: Vec<&ColumnBatch> = self.datasets.iter().map(|d| &d.batch).collect();
        ColumnBatch::concat(&parts)
    }

    /// All rows as a chunk list — one shared chunk per dataset, zero row
    /// copies (the execution-input form; [`MicroBatch::concat`] remains
    /// as the materializing reference).
    pub fn chunked(&self) -> Result<ChunkedBatch> {
        let first = self
            .datasets
            .first()
            .ok_or_else(|| Error::Schema("empty concat".into()))?;
        let mut out = ChunkedBatch::new(Arc::clone(&first.batch.schema));
        for d in &self.datasets {
            out.push_arc(Arc::new(d.batch.clone()))?;
        }
        Ok(out)
    }

    /// Append datasets from another micro-batch (re-buffered data joining
    /// newly polled data, Alg. 1 line 7).
    pub fn absorb(&mut self, other: MicroBatch) {
        self.datasets.extend(other.datasets);
        self.datasets.sort_by_key(|d| (d.created_at, d.id));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn ds(id: u64, t: f64, rows: usize) -> Dataset {
        let schema = Schema::new(vec![Field::f32("x")]);
        let batch =
            ColumnBatch::new(schema, vec![Column::F32(vec![1.0; rows].into())]).unwrap();
        Dataset {
            id,
            created_at: Time::from_secs_f64(t),
            event_time: Time::from_secs_f64(t),
            batch,
            wire_bytes: rows * 65,
        }
    }

    #[test]
    fn aggregates_over_datasets() {
        let mb = MicroBatch::new(vec![ds(0, 1.0, 10), ds(1, 2.0, 20)]);
        assert_eq!(mb.num_datasets(), 2);
        assert_eq!(mb.rows(), 30);
        assert_eq!(mb.wire_bytes(), 30 * 65);
        assert_eq!(mb.oldest_created_at().unwrap().as_secs_f64(), 1.0);
        assert_eq!(mb.newest_event_time().unwrap().as_secs_f64(), 2.0);
    }

    #[test]
    fn concat_merges_rows() {
        let mb = MicroBatch::new(vec![ds(0, 1.0, 3), ds(1, 2.0, 4)]);
        assert_eq!(mb.concat().unwrap().rows(), 7);
    }

    #[test]
    fn chunked_shares_dataset_rows() {
        let mb = MicroBatch::new(vec![ds(0, 1.0, 3), ds(1, 2.0, 4)]);
        let c = mb.chunked().unwrap();
        assert_eq!(c.num_chunks(), 2);
        assert_eq!(c.rows(), 7);
        assert!(c.chunks()[0].columns[0].shares_memory(&mb.datasets[0].batch.columns[0]));
        assert_eq!(c.coalesce(), mb.concat().unwrap());
        assert!(MicroBatch::default().chunked().is_err(), "empty mirrors concat");
    }

    #[test]
    fn absorb_keeps_creation_order() {
        let mut a = MicroBatch::new(vec![ds(1, 2.0, 1)]);
        a.absorb(MicroBatch::new(vec![ds(0, 1.0, 1)]));
        assert_eq!(a.datasets[0].id, 0);
        assert_eq!(a.datasets[1].id, 1);
    }

    #[test]
    fn empty_micro_batch() {
        let mb = MicroBatch::default();
        assert!(mb.is_empty());
        assert!(mb.oldest_created_at().is_none());
    }
}
