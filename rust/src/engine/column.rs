//! Columnar storage: shared buffers, typed columns, schemas and batches.
//!
//! Matches the layout the AOT artifacts expect (f32 data columns, i32 key
//! columns, and a row-validity mask — filtered rows stay in place and are
//! compacted only at shuffle boundaries, like columnar engines do).
//!
//! # Buffer sharing and copy-on-write
//!
//! Column data lives in immutable [`Buffer`]s: an `Arc<Vec<T>>` plus an
//! `(offset, len)` view window. `clone()` and `slice()` are O(1) pointer
//! bumps; two batches may alias the same allocation. Nothing ever mutates
//! a buffer in place — kernels that change data (filter, sort, join
//! materialization, aggregation) write *fresh* buffers and leave their
//! inputs untouched, so aliasing is always safe. The one appender
//! ([`crate::engine::window::WindowState`]'s snapshot cache) extends its
//! accumulation vectors only while it holds the sole `Arc` reference and
//! falls back to copy-on-write otherwise.
//!
//! Row liveness is split out of the columns into [`Validity`]: a filter
//! writes only a new mask (plus O(#columns) Arc clones), never a column
//! byte. The live-row count is cached at mask construction, so
//! [`ColumnBatch::live_rows`] is O(1).

use crate::error::{Error, Result};
use std::fmt;
use std::sync::Arc;

/// A shared, immutable, sliceable run of `T`: `Arc`'d storage plus an
/// `(offset, len)` view. Cloning and slicing are O(1); the data is never
/// mutated through a `Buffer`.
pub struct Buffer<T> {
    data: Arc<Vec<T>>,
    offset: usize,
    len: usize,
}

impl<T> Buffer<T> {
    /// Wrap an owned vector (no copy).
    pub fn from_vec(v: Vec<T>) -> Buffer<T> {
        let len = v.len();
        Buffer { data: Arc::new(v), offset: 0, len }
    }

    /// View `[offset, offset+len)` of an existing allocation (no copy).
    pub fn view(data: Arc<Vec<T>>, offset: usize, len: usize) -> Buffer<T> {
        assert!(
            offset + len <= data.len(),
            "buffer view [{offset}, {offset}+{len}) out of bounds for {}",
            data.len()
        );
        Buffer { data, offset, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[T] {
        &self.data[self.offset..self.offset + self.len]
    }

    /// O(1) sub-view `[start, start+len)` relative to this view.
    pub fn slice(&self, start: usize, len: usize) -> Buffer<T> {
        assert!(start + len <= self.len, "slice [{start}, {start}+{len}) of {}", self.len);
        Buffer { data: Arc::clone(&self.data), offset: self.offset + start, len }
    }

    /// True when both views alias the same allocation (the zero-copy
    /// invariant the property tests pin down).
    pub fn shares_memory(&self, other: &Buffer<T>) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }
}

impl<T> Clone for Buffer<T> {
    fn clone(&self) -> Buffer<T> {
        Buffer { data: Arc::clone(&self.data), offset: self.offset, len: self.len }
    }
}

impl<T> std::ops::Deref for Buffer<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T> From<Vec<T>> for Buffer<T> {
    fn from(v: Vec<T>) -> Buffer<T> {
        Buffer::from_vec(v)
    }
}

impl<'a, T> IntoIterator for &'a Buffer<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<T: PartialEq> PartialEq for Buffer<T> {
    /// Content equality (views over different allocations compare equal
    /// when their visible elements agree).
    fn eq(&self, other: &Buffer<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: fmt::Debug> fmt::Debug for Buffer<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.as_slice().fmt(f)
    }
}

/// Column element type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// A named, typed column slot in a schema.
#[derive(Clone, Debug, PartialEq)]
pub struct Field {
    pub name: String,
    pub dtype: DType,
}

impl Field {
    pub fn f32(name: &str) -> Field {
        Field { name: name.to_string(), dtype: DType::F32 }
    }

    pub fn i32(name: &str) -> Field {
        Field { name: name.to_string(), dtype: DType::I32 }
    }
}

/// An ordered set of fields.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Schema {
    pub fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Arc<Schema> {
        Arc::new(Schema { fields })
    }

    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.fields
            .iter()
            .position(|f| f.name == name)
            .ok_or_else(|| Error::Schema(format!("unknown column `{name}`")))
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }
}

/// A single column's values (a typed [`Buffer`] view).
#[derive(Clone, Debug, PartialEq)]
pub enum Column {
    F32(Buffer<f32>),
    I32(Buffer<i32>),
}

impl Column {
    pub fn len(&self) -> usize {
        match self {
            Column::F32(v) => v.len(),
            Column::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Column::F32(_) => DType::F32,
            Column::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Column::F32(v) => Ok(v.as_slice()),
            Column::I32(_) => Err(Error::Schema("expected f32 column".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Column::I32(v) => Ok(v.as_slice()),
            Column::F32(_) => Err(Error::Schema("expected i32 column".into())),
        }
    }

    /// Value at `i` as f64 (for predicates that work across types).
    /// Kernels should prefer matching the dtype once and iterating the
    /// typed slice; this per-row dispatch is for cold paths.
    pub fn get_f64(&self, i: usize) -> f64 {
        match self {
            Column::F32(v) => v[i] as f64,
            Column::I32(v) => v[i] as f64,
        }
    }

    /// Gather rows by index (materializes a fresh buffer).
    pub fn take(&self, idx: &[usize]) -> Column {
        match self {
            Column::F32(v) => {
                Column::F32(idx.iter().map(|&i| v[i]).collect::<Vec<f32>>().into())
            }
            Column::I32(v) => {
                Column::I32(idx.iter().map(|&i| v[i]).collect::<Vec<i32>>().into())
            }
        }
    }

    /// Concatenate many columns of the same dtype. A single part is an
    /// O(1) view clone; multiple parts copy into one fresh buffer.
    pub fn concat(parts: &[&Column]) -> Result<Column> {
        let first = parts.first().ok_or_else(|| Error::Schema("empty concat".into()))?;
        if parts.len() == 1 {
            return Ok((*first).clone());
        }
        match first {
            Column::F32(_) => {
                let total: usize = parts.iter().map(|p| p.len()).sum();
                let mut out = Vec::with_capacity(total);
                for p in parts {
                    out.extend_from_slice(p.as_f32()?);
                }
                Ok(Column::F32(out.into()))
            }
            Column::I32(_) => {
                let total: usize = parts.iter().map(|p| p.len()).sum();
                let mut out = Vec::with_capacity(total);
                for p in parts {
                    out.extend_from_slice(p.as_i32()?);
                }
                Ok(Column::I32(out.into()))
            }
        }
    }

    /// Contiguous view `[start, start+len)` — O(1), shares the allocation.
    pub fn slice(&self, start: usize, len: usize) -> Column {
        match self {
            Column::F32(v) => Column::F32(v.slice(start, len)),
            Column::I32(v) => Column::I32(v.slice(start, len)),
        }
    }

    /// Bytes of this column's visible (allocated-view) representation.
    pub fn bytes(&self) -> usize {
        self.len() * 4
    }

    /// True when both columns alias the same allocation.
    pub fn shares_memory(&self, other: &Column) -> bool {
        match (self, other) {
            (Column::F32(a), Column::F32(b)) => a.shares_memory(b),
            (Column::I32(a), Column::I32(b)) => a.shares_memory(b),
            _ => false,
        }
    }
}

/// Row liveness for a batch, split out of the column data so filters
/// rewrite only the mask. `None` mask means "every row live" (the common
/// case — no allocation); the live count is cached at construction, so
/// [`Validity::live`] is O(1).
#[derive(Clone, Debug)]
pub struct Validity {
    rows: usize,
    live: usize,
    mask: Option<Buffer<u8>>,
}

impl Validity {
    /// All `rows` rows live; allocates nothing.
    pub fn all_live(rows: usize) -> Validity {
        Validity { rows, live: rows, mask: None }
    }

    /// From an explicit 0/1 mask (nonzero = live). Counts live rows once;
    /// an all-live mask is normalized to the no-mask representation.
    pub fn from_mask(mask: Vec<u8>) -> Validity {
        let rows = mask.len();
        let live = mask.iter().filter(|&&v| v != 0).count();
        if live == rows {
            Validity::all_live(rows)
        } else {
            Validity { rows, live, mask: Some(mask.into()) }
        }
    }

    /// From a shared mask view with a pre-counted live total (the window
    /// snapshot cache tracks live counts incrementally).
    pub(crate) fn from_parts(mask: Buffer<u8>, live: usize) -> Validity {
        let rows = mask.len();
        debug_assert_eq!(live, mask.iter().filter(|&&v| v != 0).count());
        if live == rows {
            Validity::all_live(rows)
        } else {
            Validity { rows, live, mask: Some(mask) }
        }
    }

    /// From an owned mask whose live count the producing kernel already
    /// accumulated in its sweep (saves the recount pass of
    /// [`Validity::from_mask`]).
    pub(crate) fn from_parts_counted(mask: Vec<u8>, live: usize) -> Validity {
        Validity::from_parts(mask.into(), live)
    }

    /// Total rows (live + dead).
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Live rows — O(1), cached.
    pub fn live(&self) -> usize {
        self.live
    }

    pub fn is_live(&self, i: usize) -> bool {
        match &self.mask {
            None => {
                assert!(i < self.rows, "row {i} of {}", self.rows);
                true
            }
            Some(m) => m[i] != 0,
        }
    }

    /// Mask byte at `i` (1 = live, 0 = dead).
    pub fn get(&self, i: usize) -> u8 {
        self.is_live(i) as u8
    }

    /// The explicit mask, if one is materialized (`None` = all live).
    /// Kernels hoist this out of their row loops.
    pub fn mask(&self) -> Option<&[u8]> {
        self.mask.as_ref().map(|m| m.as_slice())
    }

    /// Materialize the mask as a 0/1 vector (test/marshaling helper).
    pub fn to_vec(&self) -> Vec<u8> {
        match &self.mask {
            None => vec![1; self.rows],
            Some(m) => m.iter().map(|&v| (v != 0) as u8).collect(),
        }
    }

    /// Set one row's liveness (copy-on-write; test/tooling path).
    pub fn set_live(&mut self, i: usize, live: bool) {
        assert!(i < self.rows, "row {i} of {}", self.rows);
        let mut mask = self.to_vec();
        mask[i] = live as u8;
        *self = Validity::from_mask(mask);
    }

    /// O(1) view slice for the no-mask case; with a mask, an O(len)
    /// recount of the window (the mask bytes themselves are shared).
    pub fn slice(&self, start: usize, len: usize) -> Validity {
        assert!(start + len <= self.rows, "slice [{start}, {start}+{len}) of {}", self.rows);
        match &self.mask {
            None => Validity::all_live(len),
            Some(m) => {
                let view = m.slice(start, len);
                let live = view.iter().filter(|&&v| v != 0).count();
                Validity::from_parts(view, live)
            }
        }
    }

    /// Concatenate; all-live parts concatenate to all-live without
    /// materializing anything.
    pub fn concat(parts: &[&Validity]) -> Validity {
        let rows: usize = parts.iter().map(|p| p.rows).sum();
        if parts.iter().all(|p| p.mask.is_none()) {
            return Validity::all_live(rows);
        }
        let mut mask = Vec::with_capacity(rows);
        for p in parts {
            match &p.mask {
                None => mask.resize(mask.len() + p.rows, 1),
                Some(m) => mask.extend_from_slice(m.as_slice()),
            }
        }
        let live = parts.iter().map(|p| p.live).sum();
        Validity { rows, live, mask: Some(mask.into()) }
    }
}

impl PartialEq for Validity {
    /// Logical equality: same row count and same per-row liveness,
    /// regardless of representation (mask vs. no-mask).
    fn eq(&self, other: &Validity) -> bool {
        if self.rows != other.rows || self.live != other.live {
            return false;
        }
        match (&self.mask, &other.mask) {
            (None, None) => true,
            _ => (0..self.rows).all(|i| self.is_live(i) == other.is_live(i)),
        }
    }
}

/// A batch: schema + shared columns + row-validity.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnBatch {
    pub schema: Arc<Schema>,
    pub columns: Vec<Column>,
    /// Row liveness (1 = live, 0 = filtered/padding), with a cached live
    /// count. Kernels AND into a *fresh* mask; columns are never touched.
    pub validity: Validity,
}

impl ColumnBatch {
    /// Build with all rows valid; checks column/schema consistency.
    pub fn new(schema: Arc<Schema>, columns: Vec<Column>) -> Result<ColumnBatch> {
        if columns.len() != schema.len() {
            return Err(Error::Schema(format!(
                "{} columns for schema of {}",
                columns.len(),
                schema.len()
            )));
        }
        let rows = columns.first().map(|c| c.len()).unwrap_or(0);
        for (c, f) in columns.iter().zip(&schema.fields) {
            if c.len() != rows {
                return Err(Error::Schema(format!("ragged column `{}`", f.name)));
            }
            if c.dtype() != f.dtype {
                return Err(Error::Schema(format!("dtype mismatch on `{}`", f.name)));
            }
        }
        Ok(ColumnBatch { schema, columns, validity: Validity::all_live(rows) })
    }

    /// Empty batch with the given schema.
    pub fn empty(schema: Arc<Schema>) -> ColumnBatch {
        let columns = schema
            .fields
            .iter()
            .map(|f| match f.dtype {
                DType::F32 => Column::F32(Vec::new().into()),
                DType::I32 => Column::I32(Vec::new().into()),
            })
            .collect();
        ColumnBatch { schema, columns, validity: Validity::all_live(0) }
    }

    /// Total rows (live + dead).
    pub fn rows(&self) -> usize {
        self.validity.len()
    }

    /// Live rows only — O(1), cached in the validity.
    pub fn live_rows(&self) -> usize {
        self.validity.live()
    }

    /// Column accessor by name.
    pub fn column(&self, name: &str) -> Result<&Column> {
        Ok(&self.columns[self.schema.index_of(name)?])
    }

    /// **Allocated** in-memory bytes of this batch's view: all rows, live
    /// *and* dead, plus one mask byte per row. This is what buffers
    /// actually occupy and what the device cost models / admission sizing
    /// charge (dead rows still travel through kernels until a shuffle
    /// compacts them). For the live-data size, use
    /// [`ColumnBatch::live_bytes`].
    pub fn alloc_bytes(&self) -> usize {
        self.columns.iter().map(|c| c.bytes()).sum::<usize>() + self.rows()
    }

    /// Bytes of the *live* rows only (columns + mask byte per live row) —
    /// the post-compaction footprint.
    pub fn live_bytes(&self) -> usize {
        self.live_rows() * (4 * self.columns.len() + 1)
    }

    /// Concatenate batches that share a schema. A single part is an O(1)
    /// clone (no copy).
    pub fn concat(parts: &[&ColumnBatch]) -> Result<ColumnBatch> {
        let first = parts.first().ok_or_else(|| Error::Schema("empty concat".into()))?;
        let schema = Arc::clone(&first.schema);
        for p in parts {
            if p.schema != schema {
                return Err(Error::Schema("concat over mixed schemas".into()));
            }
        }
        if parts.len() == 1 {
            return Ok((*first).clone());
        }
        let mut columns = Vec::with_capacity(schema.len());
        for ci in 0..schema.len() {
            let cols: Vec<&Column> = parts.iter().map(|p| &p.columns[ci]).collect();
            columns.push(Column::concat(&cols)?);
        }
        let validity =
            Validity::concat(&parts.iter().map(|p| &p.validity).collect::<Vec<_>>());
        Ok(ColumnBatch { schema, columns, validity })
    }

    /// Contiguous row view `[start, start+len)` — O(1) per column, shares
    /// the allocations.
    pub fn slice(&self, start: usize, len: usize) -> ColumnBatch {
        ColumnBatch {
            schema: Arc::clone(&self.schema),
            columns: self.columns.iter().map(|c| c.slice(start, len)).collect(),
            validity: self.validity.slice(start, len),
        }
    }

    /// Drop dead rows (shuffle-boundary compaction). All-live batches
    /// return an O(1) clone.
    pub fn compact(&self) -> ColumnBatch {
        if self.validity.mask().is_none() {
            return self.clone();
        }
        let idx: Vec<usize> =
            (0..self.rows()).filter(|&i| self.validity.is_live(i)).collect();
        ColumnBatch {
            schema: Arc::clone(&self.schema),
            columns: self.columns.iter().map(|c| c.take(&idx)).collect(),
            validity: Validity::all_live(idx.len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("speed"), Field::i32("lane")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![10.0, 20.0, 30.0].into()),
                Column::I32(vec![1, 2, 3].into()),
            ],
        )
        .unwrap()
    }

    #[test]
    fn construction_checks_consistency() {
        let schema = Schema::new(vec![Field::f32("a")]);
        assert!(ColumnBatch::new(schema.clone(), vec![]).is_err());
        assert!(
            ColumnBatch::new(schema.clone(), vec![Column::I32(vec![1].into())]).is_err()
        );
        assert!(ColumnBatch::new(schema, vec![Column::F32(vec![1.0].into())]).is_ok());
    }

    #[test]
    fn ragged_columns_rejected() {
        let schema = Schema::new(vec![Field::f32("a"), Field::f32("b")]);
        let r = ColumnBatch::new(
            schema,
            vec![Column::F32(vec![1.0].into()), Column::F32(vec![1.0, 2.0].into())],
        );
        assert!(r.is_err());
    }

    #[test]
    fn column_lookup_by_name() {
        let b = demo();
        assert_eq!(b.column("speed").unwrap().as_f32().unwrap()[1], 20.0);
        assert!(b.column("nope").is_err());
    }

    #[test]
    fn concat_and_slice_round_trip() {
        let b = demo();
        let big = ColumnBatch::concat(&[&b, &b]).unwrap();
        assert_eq!(big.rows(), 6);
        let back = big.slice(3, 3);
        assert_eq!(back.columns, b.columns);
    }

    #[test]
    fn compact_drops_dead_rows() {
        let mut b = demo();
        b.validity.set_live(1, false);
        assert_eq!(b.live_rows(), 2);
        let c = b.compact();
        assert_eq!(c.rows(), 2);
        assert_eq!(c.column("speed").unwrap().as_f32().unwrap(), &[10.0, 30.0]);
    }

    #[test]
    fn take_gathers() {
        let c = Column::F32(vec![1.0, 2.0, 3.0].into());
        assert_eq!(c.take(&[2, 0]).as_f32().unwrap(), &[3.0, 1.0]);
    }

    #[test]
    fn alloc_bytes_counts_columns_and_mask() {
        let b = demo();
        assert_eq!(b.alloc_bytes(), 3 * 4 + 3 * 4 + 3);
    }

    /// Pins the allocated-vs-live distinction the cost model and admission
    /// rely on: `alloc_bytes` charges dead rows (they still move through
    /// kernels and over PCIe until a shuffle compacts them); `live_bytes`
    /// is the post-compaction footprint.
    #[test]
    fn alloc_bytes_counts_dead_rows_live_bytes_does_not() {
        let mut b = demo();
        let before = b.alloc_bytes();
        b.validity.set_live(0, false);
        b.validity.set_live(2, false);
        assert_eq!(b.alloc_bytes(), before, "alloc bytes ignore liveness");
        assert_eq!(b.live_bytes(), 4 * 2 + 1); // one live row, two columns + mask byte
        let compacted = b.compact();
        assert_eq!(compacted.alloc_bytes(), compacted.live_bytes());
    }

    #[test]
    fn clone_and_slice_share_memory() {
        let b = demo();
        let c = b.clone();
        for (x, y) in b.columns.iter().zip(&c.columns) {
            assert!(x.shares_memory(y), "clone must not copy column data");
        }
        let s = b.slice(1, 2);
        for (x, y) in b.columns.iter().zip(&s.columns) {
            assert!(x.shares_memory(y), "slice must not copy column data");
        }
        assert_eq!(s.column("speed").unwrap().as_f32().unwrap(), &[20.0, 30.0]);
    }

    #[test]
    fn single_part_concat_is_zero_copy() {
        let b = demo();
        let c = ColumnBatch::concat(&[&b]).unwrap();
        for (x, y) in b.columns.iter().zip(&c.columns) {
            assert!(x.shares_memory(y));
        }
        let multi = ColumnBatch::concat(&[&b, &b]).unwrap();
        for (x, y) in b.columns.iter().zip(&multi.columns) {
            assert!(!x.shares_memory(y), "multi-part concat materializes");
        }
    }

    #[test]
    fn validity_caches_live_count() {
        let v = Validity::from_mask(vec![1, 0, 1, 1, 0]);
        assert_eq!(v.len(), 5);
        assert_eq!(v.live(), 3);
        assert!(!v.is_live(1));
        assert_eq!(v.to_vec(), vec![1, 0, 1, 1, 0]);
        let s = v.slice(1, 3);
        assert_eq!(s.live(), 2);
        assert_eq!(s.to_vec(), vec![0, 1, 1]);
    }

    #[test]
    fn all_live_mask_normalized_away() {
        let v = Validity::from_mask(vec![1, 1, 1]);
        assert!(v.mask().is_none(), "all-live masks carry no allocation");
        assert_eq!(v, Validity::all_live(3));
    }

    #[test]
    fn validity_concat_fast_path_and_mixed() {
        let a = Validity::all_live(2);
        let b = Validity::all_live(3);
        let both = Validity::concat(&[&a, &b]);
        assert!(both.mask().is_none());
        assert_eq!(both.live(), 5);
        let c = Validity::from_mask(vec![0, 1]);
        let mixed = Validity::concat(&[&a, &c]);
        assert_eq!(mixed.to_vec(), vec![1, 1, 0, 1]);
        assert_eq!(mixed.live(), 3);
    }

    #[test]
    fn buffer_views_window_correctly() {
        let buf: Buffer<i32> = vec![0, 1, 2, 3, 4, 5].into();
        let mid = buf.slice(2, 3);
        assert_eq!(mid.as_slice(), &[2, 3, 4]);
        let inner = mid.slice(1, 1);
        assert_eq!(inner.as_slice(), &[3]);
        assert!(inner.shares_memory(&buf));
    }
}
