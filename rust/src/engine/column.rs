//! Columnar storage: typed columns, schemas and batches.
//!
//! Matches the layout the AOT artifacts expect (f32 data columns, i32 key
//! columns, and a 0/1 row-validity mask — filtered rows stay in place and
//! are compacted only at shuffle boundaries, like columnar engines do).

use crate::error::{Error, Result};
use std::sync::Arc;

/// Column element type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// A named, typed column slot in a schema.
#[derive(Clone, Debug, PartialEq)]
pub struct Field {
    pub name: String,
    pub dtype: DType,
}

impl Field {
    pub fn f32(name: &str) -> Field {
        Field { name: name.to_string(), dtype: DType::F32 }
    }

    pub fn i32(name: &str) -> Field {
        Field { name: name.to_string(), dtype: DType::I32 }
    }
}

/// An ordered set of fields.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Schema {
    pub fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<Field>) -> Arc<Schema> {
        Arc::new(Schema { fields })
    }

    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.fields
            .iter()
            .position(|f| f.name == name)
            .ok_or_else(|| Error::Schema(format!("unknown column `{name}`")))
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }
}

/// A single column's values.
#[derive(Clone, Debug, PartialEq)]
pub enum Column {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Column {
    pub fn len(&self) -> usize {
        match self {
            Column::F32(v) => v.len(),
            Column::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Column::F32(_) => DType::F32,
            Column::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Column::F32(v) => Ok(v),
            Column::I32(_) => Err(Error::Schema("expected f32 column".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Column::I32(v) => Ok(v),
            Column::F32(_) => Err(Error::Schema("expected i32 column".into())),
        }
    }

    /// Value at `i` as f64 (for predicates that work across types).
    pub fn get_f64(&self, i: usize) -> f64 {
        match self {
            Column::F32(v) => v[i] as f64,
            Column::I32(v) => v[i] as f64,
        }
    }

    /// Gather rows by index.
    pub fn take(&self, idx: &[usize]) -> Column {
        match self {
            Column::F32(v) => Column::F32(idx.iter().map(|&i| v[i]).collect()),
            Column::I32(v) => Column::I32(idx.iter().map(|&i| v[i]).collect()),
        }
    }

    /// Concatenate many columns of the same dtype.
    pub fn concat(parts: &[&Column]) -> Result<Column> {
        let first = parts.first().ok_or_else(|| Error::Schema("empty concat".into()))?;
        match first {
            Column::F32(_) => {
                let mut out = Vec::new();
                for p in parts {
                    out.extend_from_slice(p.as_f32()?);
                }
                Ok(Column::F32(out))
            }
            Column::I32(_) => {
                let mut out = Vec::new();
                for p in parts {
                    out.extend_from_slice(p.as_i32()?);
                }
                Ok(Column::I32(out))
            }
        }
    }

    /// Contiguous slice [start, start+len).
    pub fn slice(&self, start: usize, len: usize) -> Column {
        match self {
            Column::F32(v) => Column::F32(v[start..start + len].to_vec()),
            Column::I32(v) => Column::I32(v[start..start + len].to_vec()),
        }
    }

    /// Bytes of in-memory representation.
    pub fn bytes(&self) -> usize {
        self.len() * 4
    }
}

/// A batch: schema + columns + row-validity mask.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnBatch {
    pub schema: Arc<Schema>,
    pub columns: Vec<Column>,
    /// 1 = live row, 0 = filtered/padding.
    pub valid: Vec<u8>,
}

impl ColumnBatch {
    /// Build with all rows valid; checks column/schema consistency.
    pub fn new(schema: Arc<Schema>, columns: Vec<Column>) -> Result<ColumnBatch> {
        if columns.len() != schema.len() {
            return Err(Error::Schema(format!(
                "{} columns for schema of {}",
                columns.len(),
                schema.len()
            )));
        }
        let rows = columns.first().map(|c| c.len()).unwrap_or(0);
        for (c, f) in columns.iter().zip(&schema.fields) {
            if c.len() != rows {
                return Err(Error::Schema(format!("ragged column `{}`", f.name)));
            }
            if c.dtype() != f.dtype {
                return Err(Error::Schema(format!("dtype mismatch on `{}`", f.name)));
            }
        }
        Ok(ColumnBatch { schema, columns, valid: vec![1; rows] })
    }

    /// Empty batch with the given schema.
    pub fn empty(schema: Arc<Schema>) -> ColumnBatch {
        let columns = schema
            .fields
            .iter()
            .map(|f| match f.dtype {
                DType::F32 => Column::F32(Vec::new()),
                DType::I32 => Column::I32(Vec::new()),
            })
            .collect();
        ColumnBatch { schema, columns, valid: Vec::new() }
    }

    /// Total rows (live + dead).
    pub fn rows(&self) -> usize {
        self.valid.len()
    }

    /// Live rows only.
    pub fn live_rows(&self) -> usize {
        self.valid.iter().map(|&v| v as usize).sum()
    }

    /// Column accessor by name.
    pub fn column(&self, name: &str) -> Result<&Column> {
        Ok(&self.columns[self.schema.index_of(name)?])
    }

    /// In-memory bytes of the live representation.
    pub fn bytes(&self) -> usize {
        self.columns.iter().map(|c| c.bytes()).sum::<usize>() + self.valid.len()
    }

    /// Concatenate batches that share a schema.
    pub fn concat(parts: &[&ColumnBatch]) -> Result<ColumnBatch> {
        let first = parts.first().ok_or_else(|| Error::Schema("empty concat".into()))?;
        let schema = Arc::clone(&first.schema);
        for p in parts {
            if p.schema != schema {
                return Err(Error::Schema("concat over mixed schemas".into()));
            }
        }
        let mut columns = Vec::with_capacity(schema.len());
        for ci in 0..schema.len() {
            let cols: Vec<&Column> = parts.iter().map(|p| &p.columns[ci]).collect();
            columns.push(Column::concat(&cols)?);
        }
        let mut valid = Vec::new();
        for p in parts {
            valid.extend_from_slice(&p.valid);
        }
        Ok(ColumnBatch { schema, columns, valid })
    }

    /// Contiguous row slice.
    pub fn slice(&self, start: usize, len: usize) -> ColumnBatch {
        ColumnBatch {
            schema: Arc::clone(&self.schema),
            columns: self.columns.iter().map(|c| c.slice(start, len)).collect(),
            valid: self.valid[start..start + len].to_vec(),
        }
    }

    /// Drop dead rows (shuffle-boundary compaction).
    pub fn compact(&self) -> ColumnBatch {
        let idx: Vec<usize> = (0..self.rows()).filter(|&i| self.valid[i] == 1).collect();
        ColumnBatch {
            schema: Arc::clone(&self.schema),
            columns: self.columns.iter().map(|c| c.take(&idx)).collect(),
            valid: vec![1; idx.len()],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("speed"), Field::i32("lane")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![10.0, 20.0, 30.0]),
                Column::I32(vec![1, 2, 3]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn construction_checks_consistency() {
        let schema = Schema::new(vec![Field::f32("a")]);
        assert!(ColumnBatch::new(schema.clone(), vec![]).is_err());
        assert!(
            ColumnBatch::new(schema.clone(), vec![Column::I32(vec![1])]).is_err()
        );
        assert!(ColumnBatch::new(schema, vec![Column::F32(vec![1.0])]).is_ok());
    }

    #[test]
    fn ragged_columns_rejected() {
        let schema = Schema::new(vec![Field::f32("a"), Field::f32("b")]);
        let r = ColumnBatch::new(
            schema,
            vec![Column::F32(vec![1.0]), Column::F32(vec![1.0, 2.0])],
        );
        assert!(r.is_err());
    }

    #[test]
    fn column_lookup_by_name() {
        let b = demo();
        assert_eq!(b.column("speed").unwrap().as_f32().unwrap()[1], 20.0);
        assert!(b.column("nope").is_err());
    }

    #[test]
    fn concat_and_slice_round_trip() {
        let b = demo();
        let big = ColumnBatch::concat(&[&b, &b]).unwrap();
        assert_eq!(big.rows(), 6);
        let back = big.slice(3, 3);
        assert_eq!(back.columns, b.columns);
    }

    #[test]
    fn compact_drops_dead_rows() {
        let mut b = demo();
        b.valid[1] = 0;
        assert_eq!(b.live_rows(), 2);
        let c = b.compact();
        assert_eq!(c.rows(), 2);
        assert_eq!(c.column("speed").unwrap().as_f32().unwrap(), &[10.0, 30.0]);
    }

    #[test]
    fn take_gathers() {
        let c = Column::F32(vec![1.0, 2.0, 3.0]);
        assert_eq!(c.take(&[2, 0]).as_f32().unwrap(), &[3.0, 1.0]);
    }

    #[test]
    fn bytes_accounts_columns_and_mask() {
        let b = demo();
        assert_eq!(b.bytes(), 3 * 4 + 3 * 4 + 3);
    }
}
