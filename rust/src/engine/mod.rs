//! The streaming substrate: a from-scratch columnar micro-batch engine
//! (the Spark analog the paper's mechanisms are implemented into).
//!
//! * [`column`] — typed columns, schemas, batches
//! * [`chunked`] — the chunked execution representation every operator
//!   consumes and produces (Arc'd chunk lists; explicit coalesce points)
//! * [`encode`] — RLE/dictionary/delta-encoded column blocks with
//!   min/max stats (cold window state; pruning under fused filters)
//! * [`dataset`] — arrival-stamped datasets and micro-batches
//! * [`partition`] — splitting a micro-batch across `NumCores` partitions
//! * [`window`] — sliding/tumbling window state management
//! * [`ops`] — native CPU operators (scan, filter, project, aggregate,
//!   join, sort, expand, shuffle)

pub mod chunked;
pub mod column;
pub mod dataset;
pub mod encode;
pub mod ops;
pub mod partition;
pub mod sink;
pub mod window;

pub use chunked::ChunkedBatch;
pub use column::{Buffer, Column, ColumnBatch, DType, Field, Schema, Validity};
pub use dataset::{Dataset, MicroBatch};
pub use window::{WindowKind, WindowSpec, WindowState};
