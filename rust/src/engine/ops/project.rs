//! Projection: column selection and arithmetic projection.
//!
//! Selection is zero-copy: the kept columns are O(1) Arc clones of the
//! input's buffers; only `project_affine` materializes (one new column).

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, DType, Field, Schema};
use crate::error::{Error, Result};
use std::sync::Arc;

/// SELECT a subset of columns (order follows `keep`). Shares the kept
/// columns' buffers with the input.
pub fn project_select(batch: &ColumnBatch, keep: &[&str]) -> Result<ColumnBatch> {
    let mut fields = Vec::with_capacity(keep.len());
    let mut columns = Vec::with_capacity(keep.len());
    for name in keep {
        let idx = batch.schema.index_of(name)?;
        fields.push(batch.schema.fields[idx].clone());
        columns.push(batch.columns[idx].clone());
    }
    Ok(ColumnBatch {
        schema: Schema::new(fields),
        columns,
        validity: batch.validity.clone(),
    })
}

/// Append `out = alpha*a + beta*b` as a new f32 column (existing columns
/// are shared, only the new one is written).
pub fn project_affine(
    batch: &ColumnBatch,
    a: &str,
    b: &str,
    alpha: f32,
    beta: f32,
    out: &str,
) -> Result<ColumnBatch> {
    let ca = batch.column(a)?.as_f32()?;
    let cb = batch.column(b)?.as_f32()?;
    let values: Vec<f32> = ca
        .iter()
        .zip(cb)
        .map(|(x, y)| alpha * x + beta * y)
        .collect();
    let mut fields = batch.schema.fields.clone();
    fields.push(Field::f32(out));
    let mut columns = batch.columns.clone();
    columns.push(Column::F32(values.into()));
    Ok(ColumnBatch {
        schema: Schema::new(fields),
        columns,
        validity: batch.validity.clone(),
    })
}

/// Chunked column selection: indices are resolved once against the
/// shared schema, then every chunk re-shares its kept columns — O(#chunks
/// × #kept) Arc bumps, no row copies.
pub fn project_select_chunks(batch: &ChunkedBatch, keep: &[&str]) -> Result<ChunkedBatch> {
    let mut idx = Vec::with_capacity(keep.len());
    let mut fields = Vec::with_capacity(keep.len());
    for name in keep {
        let i = batch.schema().index_of(name)?;
        idx.push(i);
        fields.push(batch.schema().fields[i].clone());
    }
    let schema = Schema::new(fields);
    let mut out = ChunkedBatch::new(Arc::clone(&schema));
    for chunk in batch.chunks() {
        out.push(ColumnBatch {
            schema: Arc::clone(&schema),
            columns: idx.iter().map(|&i| chunk.columns[i].clone()).collect(),
            validity: chunk.validity.clone(),
        })?;
    }
    Ok(out)
}

/// Chunked affine projection: per-chunk fresh output column, every
/// existing column shared.
pub fn project_affine_chunks(
    batch: &ChunkedBatch,
    a: &str,
    b: &str,
    alpha: f32,
    beta: f32,
    out_name: &str,
) -> Result<ChunkedBatch> {
    let ai = batch.schema().index_of(a)?;
    let bi = batch.schema().index_of(b)?;
    if batch.schema().fields[ai].dtype != DType::F32
        || batch.schema().fields[bi].dtype != DType::F32
    {
        return Err(Error::Schema("expected f32 column".into()));
    }
    let mut fields = batch.schema().fields.clone();
    fields.push(Field::f32(out_name));
    let schema = Schema::new(fields);
    let mut out = ChunkedBatch::new(Arc::clone(&schema));
    for chunk in batch.chunks() {
        let ca = chunk.columns[ai].as_f32()?;
        let cb = chunk.columns[bi].as_f32()?;
        let values: Vec<f32> =
            ca.iter().zip(cb).map(|(x, y)| alpha * x + beta * y).collect();
        let mut columns = chunk.columns.clone();
        columns.push(Column::F32(values.into()));
        out.push(ColumnBatch {
            schema: Arc::clone(&schema),
            columns,
            validity: chunk.validity.clone(),
        })?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("a"), Field::f32("b"), Field::i32("k")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![1.0, 2.0].into()),
                Column::F32(vec![10.0, 20.0].into()),
                Column::I32(vec![7, 8].into()),
            ],
        )
        .unwrap()
    }

    #[test]
    fn select_reorders_columns() {
        let out = project_select(&batch(), &["k", "a"]).unwrap();
        assert_eq!(out.schema.fields[0].name, "k");
        assert_eq!(out.column("a").unwrap().as_f32().unwrap(), &[1.0, 2.0]);
        assert!(out.column("b").is_err());
    }

    #[test]
    fn select_shares_buffers() {
        let b = batch();
        let out = project_select(&b, &["a", "k"]).unwrap();
        assert!(b.columns[0].shares_memory(&out.columns[0]));
        assert!(b.columns[2].shares_memory(&out.columns[1]));
    }

    #[test]
    fn affine_appends_column() {
        let out = project_affine(&batch(), "a", "b", 2.0, 0.5, "mix").unwrap();
        assert_eq!(out.column("mix").unwrap().as_f32().unwrap(), &[7.0, 14.0]);
        assert_eq!(out.schema.len(), 4);
    }

    #[test]
    fn validity_preserved() {
        let mut b = batch();
        b.validity.set_live(0, false);
        let out = project_select(&b, &["a"]).unwrap();
        assert_eq!(out.validity.to_vec(), vec![0, 1]);
    }

    #[test]
    fn affine_requires_f32_columns() {
        assert!(project_affine(&batch(), "k", "b", 1.0, 1.0, "x").is_err());
    }
}
