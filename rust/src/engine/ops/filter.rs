//! Filter: predicate over one column, ANDed into the validity mask.
//!
//! Zero-copy: the output batch shares every column buffer with its input
//! (O(1) Arc clones) and only a fresh validity mask is written. The
//! kernel matches the column dtype *once* and runs a typed inner loop —
//! no per-row enum dispatch.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, Validity};
use crate::error::Result;
use std::sync::Arc;

/// Scalar predicates the workloads need (Table III WHERE/HAVING clauses).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Predicate {
    /// `col >= v`
    Ge(f64),
    /// `col < v`
    Lt(f64),
    /// `col == v`
    Eq(f64),
    /// `lo <= col < hi`
    Band(f64, f64),
}

impl Predicate {
    pub fn eval(&self, x: f64) -> bool {
        match *self {
            Predicate::Ge(v) => x >= v,
            Predicate::Lt(v) => x < v,
            Predicate::Eq(v) => x == v,
            Predicate::Band(lo, hi) => x >= lo && x < hi,
        }
    }

    /// Conservative block-stats test: could *any* value in `[min, max]`
    /// satisfy the predicate? Sound for pruning — it never answers
    /// `false` when a value in range could match, so a `false` lets a
    /// fused chain skip the per-row sweep and emit an all-dead mask
    /// (exactly what evaluating every row would have produced). See
    /// [`crate::engine::encode`] for where the bounds come from.
    pub fn can_match(&self, min: f64, max: f64) -> bool {
        match *self {
            Predicate::Ge(v) => max >= v,
            Predicate::Lt(v) => min < v,
            Predicate::Eq(v) => min <= v && v <= max,
            Predicate::Band(lo, hi) => max >= lo && min < hi,
        }
    }
}

/// Typed inner loop: one predicate branch chosen per kernel invocation,
/// then a straight-line sweep ANDing into the mask (monotone: dead rows
/// stay dead). Returns the surviving live-row count, accumulated in the
/// same pass so the caller needs no recount sweep.
fn apply_pred<T: Copy>(
    vals: &[T],
    mask: &mut [u8],
    pred: Predicate,
    to: impl Fn(T) -> f64,
) -> usize {
    let mut live = 0usize;
    match pred {
        Predicate::Ge(v) => {
            for (m, &x) in mask.iter_mut().zip(vals) {
                *m &= (to(x) >= v) as u8;
                live += *m as usize;
            }
        }
        Predicate::Lt(v) => {
            for (m, &x) in mask.iter_mut().zip(vals) {
                *m &= (to(x) < v) as u8;
                live += *m as usize;
            }
        }
        Predicate::Eq(v) => {
            for (m, &x) in mask.iter_mut().zip(vals) {
                *m &= (to(x) == v) as u8;
                live += *m as usize;
            }
        }
        Predicate::Band(lo, hi) => {
            for (m, &x) in mask.iter_mut().zip(vals) {
                let x = to(x);
                *m &= (x >= lo && x < hi) as u8;
                live += *m as usize;
            }
        }
    }
    live
}

/// Apply `pred` on `col`; dead rows stay dead (mask is monotone). Columns
/// are shared with the input — only the mask is written, in a single
/// seed + sweep (the live count comes out of the sweep itself).
pub fn filter(batch: &ColumnBatch, col: &str, pred: Predicate) -> Result<ColumnBatch> {
    let c = batch.column(col)?;
    let mut mask = batch.validity.to_vec();
    let live = match c {
        Column::F32(v) => apply_pred(v.as_slice(), &mut mask, pred, |x| x as f64),
        Column::I32(v) => apply_pred(v.as_slice(), &mut mask, pred, |x| x as f64),
    };
    Ok(ColumnBatch {
        schema: Arc::clone(&batch.schema),
        columns: batch.columns.clone(),
        validity: Validity::from_parts_counted(mask, live),
    })
}

/// Chunked filter: the per-chunk kernel runs over each chunk in place of
/// the coalesced sweep — the chunk layout is preserved, columns stay
/// shared, only fresh per-chunk masks are written.
pub fn filter_chunks(
    batch: &ChunkedBatch,
    col: &str,
    pred: Predicate,
) -> Result<ChunkedBatch> {
    // Resolve against the shared schema so an unknown column errors even
    // for an empty chunk list, exactly like the coalesced path.
    batch.schema().index_of(col)?;
    let mut out = ChunkedBatch::new(Arc::clone(batch.schema()));
    for chunk in batch.chunks() {
        out.push(filter(chunk, col, pred)?)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("v")]);
        ColumnBatch::new(schema, vec![Column::F32(vec![1.0, 2.0, 3.0, 4.0].into())])
            .unwrap()
    }

    #[test]
    fn ge_keeps_boundary() {
        let out = filter(&batch(), "v", Predicate::Ge(2.0)).unwrap();
        assert_eq!(out.validity.to_vec(), vec![0, 1, 1, 1]);
    }

    #[test]
    fn lt_excludes_boundary() {
        let out = filter(&batch(), "v", Predicate::Lt(3.0)).unwrap();
        assert_eq!(out.validity.to_vec(), vec![1, 1, 0, 0]);
    }

    #[test]
    fn eq_matches_exact() {
        let out = filter(&batch(), "v", Predicate::Eq(3.0)).unwrap();
        assert_eq!(out.validity.to_vec(), vec![0, 0, 1, 0]);
    }

    #[test]
    fn band_half_open() {
        let out = filter(&batch(), "v", Predicate::Band(2.0, 4.0)).unwrap();
        assert_eq!(out.validity.to_vec(), vec![0, 1, 1, 0]);
    }

    #[test]
    fn mask_is_monotone() {
        let mut b = batch();
        b.validity.set_live(3, false); // already dead
        let out = filter(&b, "v", Predicate::Ge(0.0)).unwrap();
        assert_eq!(out.validity.to_vec(), vec![1, 1, 1, 0]);
    }

    #[test]
    fn i32_columns_filter_typed() {
        let schema = Schema::new(vec![Field::i32("k")]);
        let b = ColumnBatch::new(schema, vec![Column::I32(vec![5, 10, 15].into())])
            .unwrap();
        let out = filter(&b, "k", Predicate::Band(6.0, 15.0)).unwrap();
        assert_eq!(out.validity.to_vec(), vec![0, 1, 0]);
    }

    #[test]
    fn output_shares_column_buffers() {
        let b = batch();
        let out = filter(&b, "v", Predicate::Ge(2.0)).unwrap();
        for (x, y) in b.columns.iter().zip(&out.columns) {
            assert!(x.shares_memory(y), "filter must not copy column data");
        }
        // And the input's own mask is untouched.
        assert_eq!(b.live_rows(), 4);
    }

    #[test]
    fn unknown_column_errors() {
        assert!(filter(&batch(), "nope", Predicate::Ge(0.0)).is_err());
    }

    /// `can_match(min, max) == false` must imply no value in the range
    /// matches — sweep each predicate against a bound lattice.
    #[test]
    fn can_match_is_sound_and_not_vacuous() {
        let preds = [
            Predicate::Ge(5.0),
            Predicate::Lt(5.0),
            Predicate::Eq(5.0),
            Predicate::Band(3.0, 7.0),
        ];
        let bounds: &[(f64, f64)] = &[
            (0.0, 2.0),
            (0.0, 5.0),
            (5.0, 5.0),
            (5.0, 9.0),
            (6.0, 9.0),
            (-2.0, 12.0),
        ];
        for p in preds {
            let mut pruned_somewhere = false;
            for &(lo, hi) in bounds {
                if p.can_match(lo, hi) {
                    continue;
                }
                pruned_somewhere = true;
                // Soundness: sample the range densely; nothing matches.
                for step in 0..=100 {
                    let x = lo + (hi - lo) * (step as f64) / 100.0;
                    assert!(!p.eval(x), "{p:?} pruned [{lo}, {hi}] but matches {x}");
                }
            }
            assert!(pruned_somewhere, "{p:?} never prunes any test bound");
        }
    }
}
