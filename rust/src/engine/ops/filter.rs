//! Filter: predicate over one column, ANDed into the validity mask.

use crate::engine::column::ColumnBatch;
use crate::error::Result;

/// Scalar predicates the workloads need (Table III WHERE/HAVING clauses).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Predicate {
    /// `col >= v`
    Ge(f64),
    /// `col < v`
    Lt(f64),
    /// `col == v`
    Eq(f64),
    /// `lo <= col < hi`
    Band(f64, f64),
}

impl Predicate {
    pub fn eval(&self, x: f64) -> bool {
        match *self {
            Predicate::Ge(v) => x >= v,
            Predicate::Lt(v) => x < v,
            Predicate::Eq(v) => x == v,
            Predicate::Band(lo, hi) => x >= lo && x < hi,
        }
    }
}

/// Apply `pred` on `col`; dead rows stay dead (mask is monotone).
pub fn filter(batch: &ColumnBatch, col: &str, pred: Predicate) -> Result<ColumnBatch> {
    let c = batch.column(col)?;
    let mut out = batch.clone();
    for i in 0..out.rows() {
        if out.valid[i] == 1 && !pred.eval(c.get_f64(i)) {
            out.valid[i] = 0;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("v")]);
        ColumnBatch::new(schema, vec![Column::F32(vec![1.0, 2.0, 3.0, 4.0])]).unwrap()
    }

    #[test]
    fn ge_keeps_boundary() {
        let out = filter(&batch(), "v", Predicate::Ge(2.0)).unwrap();
        assert_eq!(out.valid, vec![0, 1, 1, 1]);
    }

    #[test]
    fn lt_excludes_boundary() {
        let out = filter(&batch(), "v", Predicate::Lt(3.0)).unwrap();
        assert_eq!(out.valid, vec![1, 1, 0, 0]);
    }

    #[test]
    fn eq_matches_exact() {
        let out = filter(&batch(), "v", Predicate::Eq(3.0)).unwrap();
        assert_eq!(out.valid, vec![0, 0, 1, 0]);
    }

    #[test]
    fn band_half_open() {
        let out = filter(&batch(), "v", Predicate::Band(2.0, 4.0)).unwrap();
        assert_eq!(out.valid, vec![0, 1, 1, 0]);
    }

    #[test]
    fn mask_is_monotone() {
        let mut b = batch();
        b.valid[3] = 0; // already dead
        let out = filter(&b, "v", Predicate::Ge(0.0)).unwrap();
        assert_eq!(out.valid, vec![1, 1, 1, 0]);
    }

    #[test]
    fn unknown_column_errors() {
        assert!(filter(&batch(), "nope", Predicate::Ge(0.0)).is_err());
    }
}
