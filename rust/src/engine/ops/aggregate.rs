//! Hash aggregation: GROUP BY over key columns with SUM/COUNT/AVG, plus
//! optional HAVING.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, DType, Field, Schema, Validity};
use crate::engine::ops::filter::Predicate;
use crate::error::{Error, Result};
use crate::util::hash::FxHashMap;
use std::sync::Arc;

/// Aggregate function.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggFunc {
    Sum,
    Count,
    Avg,
}

/// One aggregate output: `func(value_col) AS out`.
#[derive(Clone, Debug)]
pub struct AggSpec {
    pub func: AggFunc,
    pub value_col: String,
    pub out: String,
}

impl AggSpec {
    pub fn sum(col: &str, out: &str) -> AggSpec {
        AggSpec { func: AggFunc::Sum, value_col: col.into(), out: out.into() }
    }

    pub fn count(out: &str) -> AggSpec {
        // COUNT(*) needs no value column; keep a placeholder.
        AggSpec { func: AggFunc::Count, value_col: String::new(), out: out.into() }
    }

    pub fn avg(col: &str, out: &str) -> AggSpec {
        AggSpec { func: AggFunc::Avg, value_col: col.into(), out: out.into() }
    }
}

/// GROUP BY `group_cols` computing `aggs`; output rows are one per group,
/// ordered by first appearance (deterministic). `having` filters on an
/// output aggregate column.
pub fn hash_aggregate(
    batch: &ColumnBatch,
    group_cols: &[&str],
    aggs: &[AggSpec],
    having: Option<(&str, Predicate)>,
) -> Result<ColumnBatch> {
    hash_aggregate_parts(&batch.schema, &[batch], group_cols, aggs, having)
}

/// Chunked aggregation: one group table fed chunk by chunk in order, so
/// first-appearance group order — and therefore the output — is
/// identical to aggregating the coalesced batch. The result is a single
/// fresh chunk (aggregation materializes by nature).
pub fn hash_aggregate_chunks(
    batch: &ChunkedBatch,
    group_cols: &[&str],
    aggs: &[AggSpec],
    having: Option<(&str, Predicate)>,
) -> Result<ChunkedBatch> {
    let parts: Vec<&ColumnBatch> = batch.chunks().iter().map(|c| c.as_ref()).collect();
    let out = hash_aggregate_parts(batch.schema(), &parts, group_cols, aggs, having)?;
    Ok(ChunkedBatch::from_batch(out))
}

/// Shared core: aggregate over an ordered part list (a coalesced batch
/// is the one-part case). `schema` is the parts' common schema — used to
/// resolve columns so errors surface even for an empty part list.
fn hash_aggregate_parts(
    schema: &Arc<Schema>,
    parts: &[&ColumnBatch],
    group_cols: &[&str],
    aggs: &[AggSpec],
    having: Option<(&str, Predicate)>,
) -> Result<ColumnBatch> {
    if group_cols.is_empty() {
        return Err(Error::Plan("aggregate needs at least one group column".into()));
    }
    let key_idx: Vec<usize> = group_cols
        .iter()
        .map(|c| schema.index_of(c))
        .collect::<Result<_>>()?;
    // Pre-resolve value column indices (COUNT needs none), checking the
    // dtype once against the schema.
    let val_idx: Vec<Option<usize>> = aggs
        .iter()
        .map(|a| {
            if a.func == AggFunc::Count {
                Ok(None)
            } else {
                let i = schema.index_of(&a.value_col)?;
                if schema.fields[i].dtype != DType::F32 {
                    return Err(Error::Schema("expected f32 column".into()));
                }
                Ok(Some(i))
            }
        })
        .collect::<Result<_>>()?;

    // Group index: composite i64-encoded key -> dense group slot.
    let mut slots: FxHashMap<Vec<i64>, usize> = FxHashMap::default();
    let mut order: Vec<Vec<i64>> = Vec::new();
    let mut sums: Vec<Vec<f64>> = Vec::new();
    let mut counts: Vec<f64> = Vec::new();

    // Scratch key reused across rows; cloned only on first occurrence.
    let mut key: Vec<i64> = Vec::with_capacity(key_idx.len());
    for part in parts {
        // Per-part hoists: key/value columns and the validity mask.
        let key_cols: Vec<&Column> =
            key_idx.iter().map(|&ci| &part.columns[ci]).collect();
        let value_cols: Vec<Option<&[f32]>> = val_idx
            .iter()
            .map(|vi| vi.map(|i| part.columns[i].as_f32().expect("dtype checked")))
            .collect();
        let mask = part.validity.mask();
        for row in 0..part.rows() {
            if let Some(m) = mask {
                if m[row] == 0 {
                    continue;
                }
            }
            key.clear();
            for kc in &key_cols {
                key.push(match kc {
                    Column::I32(v) => v[row] as i64,
                    Column::F32(v) => v[row].to_bits() as i64,
                });
            }
            let slot = match slots.get(&key) {
                Some(&s) => s,
                None => {
                    let s = order.len();
                    slots.insert(key.clone(), s);
                    order.push(key.clone());
                    sums.push(vec![0.0; aggs.len()]);
                    counts.push(0.0);
                    s
                }
            };
            counts[slot] += 1.0;
            for (ai, vc) in value_cols.iter().enumerate() {
                if let Some(vals) = vc {
                    sums[slot][ai] += vals[row] as f64;
                }
            }
        }
    }

    // Assemble output schema: group keys + aggregate columns.
    let mut fields: Vec<Field> = key_idx
        .iter()
        .map(|&ci| schema.fields[ci].clone())
        .collect();
    for a in aggs {
        fields.push(Field::f32(&a.out));
    }
    let n_groups = order.len();
    let mut columns: Vec<Column> = Vec::with_capacity(fields.len());
    for (k, &ci) in key_idx.iter().enumerate() {
        match schema.fields[ci].dtype {
            DType::I32 => columns.push(Column::I32(
                order.iter().map(|key| key[k] as i32).collect::<Vec<i32>>().into(),
            )),
            DType::F32 => columns.push(Column::F32(
                order
                    .iter()
                    .map(|key| f32::from_bits(key[k] as u32))
                    .collect::<Vec<f32>>()
                    .into(),
            )),
        }
    }
    for (ai, a) in aggs.iter().enumerate() {
        let vals: Vec<f32> = (0..n_groups)
            .map(|g| match a.func {
                AggFunc::Sum => sums[g][ai] as f32,
                AggFunc::Count => counts[g] as f32,
                AggFunc::Avg => (sums[g][ai] / counts[g].max(1.0)) as f32,
            })
            .collect();
        columns.push(Column::F32(vals.into()));
    }
    let mut out = ColumnBatch {
        schema: Schema::new(fields),
        columns,
        validity: Validity::all_live(n_groups),
    };
    if let Some((col, pred)) = having {
        out = crate::engine::ops::filter::filter(&out, col, pred)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::i32("g"), Field::f32("v")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::I32(vec![1, 2, 1, 2, 1].into()),
                Column::F32(vec![10.0, 20.0, 30.0, 40.0, 50.0].into()),
            ],
        )
        .unwrap()
    }

    #[test]
    fn sum_count_avg_per_group() {
        let out = hash_aggregate(
            &batch(),
            &["g"],
            &[
                AggSpec::sum("v", "s"),
                AggSpec::count("c"),
                AggSpec::avg("v", "m"),
            ],
            None,
        )
        .unwrap();
        assert_eq!(out.rows(), 2);
        assert_eq!(out.column("g").unwrap().as_i32().unwrap(), &[1, 2]);
        assert_eq!(out.column("s").unwrap().as_f32().unwrap(), &[90.0, 60.0]);
        assert_eq!(out.column("c").unwrap().as_f32().unwrap(), &[3.0, 2.0]);
        assert_eq!(out.column("m").unwrap().as_f32().unwrap(), &[30.0, 30.0]);
    }

    #[test]
    fn dead_rows_excluded() {
        let mut b = batch();
        b.validity.set_live(4, false); // drop the 50.0 in group 1
        let out =
            hash_aggregate(&b, &["g"], &[AggSpec::sum("v", "s")], None).unwrap();
        assert_eq!(out.column("s").unwrap().as_f32().unwrap(), &[40.0, 60.0]);
    }

    #[test]
    fn having_filters_groups() {
        let out = hash_aggregate(
            &batch(),
            &["g"],
            &[AggSpec::avg("v", "m")],
            Some(("m", Predicate::Lt(31.0))),
        )
        .unwrap();
        // Both groups average 30.0 < 31.0.
        assert_eq!(out.live_rows(), 2);
        let out2 = hash_aggregate(
            &batch(),
            &["g"],
            &[AggSpec::sum("v", "s")],
            Some(("s", Predicate::Ge(80.0))),
        )
        .unwrap();
        assert_eq!(out2.live_rows(), 1);
    }

    #[test]
    fn multi_key_grouping() {
        let schema = Schema::new(vec![Field::i32("a"), Field::i32("b"), Field::f32("v")]);
        let b = ColumnBatch::new(
            schema,
            vec![
                Column::I32(vec![1, 1, 2].into()),
                Column::I32(vec![5, 6, 5].into()),
                Column::F32(vec![1.0, 2.0, 3.0].into()),
            ],
        )
        .unwrap();
        let out =
            hash_aggregate(&b, &["a", "b"], &[AggSpec::count("c")], None).unwrap();
        assert_eq!(out.rows(), 3); // (1,5), (1,6), (2,5)
    }

    #[test]
    fn f32_group_keys_supported() {
        let schema = Schema::new(vec![Field::f32("g"), Field::f32("v")]);
        let b = ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![0.5, 0.5, 1.5].into()),
                Column::F32(vec![1.0, 2.0, 3.0].into()),
            ],
        )
        .unwrap();
        let out = hash_aggregate(&b, &["g"], &[AggSpec::sum("v", "s")], None).unwrap();
        assert_eq!(out.rows(), 2);
        assert_eq!(out.column("g").unwrap().as_f32().unwrap(), &[0.5, 1.5]);
        assert_eq!(out.column("s").unwrap().as_f32().unwrap(), &[3.0, 3.0]);
    }

    #[test]
    fn empty_group_cols_rejected() {
        assert!(hash_aggregate(&batch(), &[], &[AggSpec::count("c")], None).is_err());
    }
}
