//! Shuffle: hash repartition by key column (the exchange before a
//! partition-crossing aggregation/join). Compacts dead rows — the shuffle
//! boundary is where columnar engines drop filtered data.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{ColumnBatch, Validity};
use crate::engine::ops::for_each_live_key;
use crate::error::Result;
use std::sync::Arc;

fn hash64(x: i64) -> u64 {
    // splitmix64 finalizer — cheap, well-distributed.
    let mut z = x as u64;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Partition live rows of `batch` into `n` outputs by hash of `key`.
pub fn shuffle(batch: &ColumnBatch, key: &str, n: usize) -> Result<Vec<ColumnBatch>> {
    assert!(n > 0);
    let kc = batch.column(key)?;
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); n];
    for_each_live_key(kc, &batch.validity, |row, bits| {
        buckets[(hash64(bits) % n as u64) as usize].push(row);
    });
    Ok(buckets
        .into_iter()
        .map(|idx| ColumnBatch {
            schema: Arc::clone(&batch.schema),
            columns: batch.columns.iter().map(|c| c.take(&idx)).collect(),
            validity: Validity::all_live(idx.len()),
        })
        .collect())
}

/// Chunked shuffle: each chunk is bucketed independently and every
/// partition accumulates its per-chunk gathers as chunks. Chunk-major
/// traversal preserves global row order per partition, so each
/// partition's coalesced content equals the coalesced-input shuffle.
pub fn shuffle_chunks(
    batch: &ChunkedBatch,
    key: &str,
    n: usize,
) -> Result<Vec<ChunkedBatch>> {
    assert!(n > 0);
    let ki = batch.schema().index_of(key)?;
    let mut parts: Vec<ChunkedBatch> =
        (0..n).map(|_| ChunkedBatch::new(Arc::clone(batch.schema()))).collect();
    for chunk in batch.chunks() {
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); n];
        for_each_live_key(&chunk.columns[ki], &chunk.validity, |row, bits| {
            buckets[(hash64(bits) % n as u64) as usize].push(row);
        });
        for (p, idx) in buckets.into_iter().enumerate() {
            if idx.is_empty() {
                continue;
            }
            parts[p].push(ColumnBatch {
                schema: Arc::clone(&chunk.schema),
                columns: chunk.columns.iter().map(|c| c.take(&idx)).collect(),
                validity: Validity::all_live(idx.len()),
            })?;
        }
    }
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, Field, Schema};

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::i32("k"), Field::f32("v")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::I32((0..100).collect::<Vec<i32>>().into()),
                Column::F32((0..100).map(|i| i as f32).collect::<Vec<f32>>().into()),
            ],
        )
        .unwrap()
    }

    #[test]
    fn partitions_cover_all_live_rows() {
        let parts = shuffle(&batch(), "k", 4).unwrap();
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.rows()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn same_key_same_partition() {
        let schema = Schema::new(vec![Field::i32("k")]);
        let b = ColumnBatch::new(schema, vec![Column::I32(vec![7, 7, 7, 8].into())])
            .unwrap();
        let parts = shuffle(&b, "k", 3).unwrap();
        let with_seven: Vec<usize> = parts
            .iter()
            .enumerate()
            .filter(|(_, p)| p.column("k").unwrap().as_i32().unwrap().contains(&7))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(with_seven.len(), 1);
        assert!(parts[with_seven[0]].rows() >= 3);
    }

    #[test]
    fn dead_rows_dropped() {
        let mut b = batch();
        for i in 0..50 {
            b.validity.set_live(i, false);
        }
        let parts = shuffle(&b, "k", 4).unwrap();
        let total: usize = parts.iter().map(|p| p.rows()).sum();
        assert_eq!(total, 50);
        assert!(parts.iter().all(|p| p.live_rows() == p.rows()));
    }

    #[test]
    fn distribution_roughly_uniform() {
        let parts = shuffle(&batch(), "k", 4).unwrap();
        for p in &parts {
            assert!(p.rows() > 10, "skewed bucket: {}", p.rows());
        }
    }
}
