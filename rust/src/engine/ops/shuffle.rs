//! Shuffle: hash repartition by key column (the exchange before a
//! partition-crossing aggregation/join). Compacts dead rows — the shuffle
//! boundary is where columnar engines drop filtered data.

use crate::engine::column::{Column, ColumnBatch};
use crate::error::Result;

fn hash64(x: i64) -> u64 {
    // splitmix64 finalizer — cheap, well-distributed.
    let mut z = x as u64;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Partition live rows of `batch` into `n` outputs by hash of `key`.
pub fn shuffle(batch: &ColumnBatch, key: &str, n: usize) -> Result<Vec<ColumnBatch>> {
    assert!(n > 0);
    let kc = batch.column(key)?;
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); n];
    for row in 0..batch.rows() {
        if batch.valid[row] == 0 {
            continue;
        }
        let bits = match kc {
            Column::I32(v) => v[row] as i64,
            Column::F32(v) => v[row].to_bits() as i64,
        };
        buckets[(hash64(bits) % n as u64) as usize].push(row);
    }
    Ok(buckets
        .into_iter()
        .map(|idx| ColumnBatch {
            schema: batch.schema.clone(),
            columns: batch.columns.iter().map(|c| c.take(&idx)).collect(),
            valid: vec![1; idx.len()],
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Field, Schema};

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::i32("k"), Field::f32("v")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::I32((0..100).collect()),
                Column::F32((0..100).map(|i| i as f32).collect()),
            ],
        )
        .unwrap()
    }

    #[test]
    fn partitions_cover_all_live_rows() {
        let parts = shuffle(&batch(), "k", 4).unwrap();
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.rows()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn same_key_same_partition() {
        let schema = Schema::new(vec![Field::i32("k")]);
        let b = ColumnBatch::new(schema, vec![Column::I32(vec![7, 7, 7, 8])]).unwrap();
        let parts = shuffle(&b, "k", 3).unwrap();
        let with_seven: Vec<usize> = parts
            .iter()
            .enumerate()
            .filter(|(_, p)| p.column("k").unwrap().as_i32().unwrap().contains(&7))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(with_seven.len(), 1);
        assert_eq!(parts[with_seven[0]].rows() >= 3, true);
    }

    #[test]
    fn dead_rows_dropped() {
        let mut b = batch();
        for i in 0..50 {
            b.valid[i] = 0;
        }
        let parts = shuffle(&b, "k", 4).unwrap();
        let total: usize = parts.iter().map(|p| p.rows()).sum();
        assert_eq!(total, 50);
        assert!(parts.iter().all(|p| p.valid.iter().all(|&v| v == 1)));
    }

    #[test]
    fn distribution_roughly_uniform() {
        let parts = shuffle(&batch(), "k", 4).unwrap();
        for p in &parts {
            assert!(p.rows() > 10, "skewed bucket: {}", p.rows());
        }
    }
}
