//! Sort by one column (stable; dead rows sink to the end).

use crate::engine::column::ColumnBatch;
use crate::error::Result;

/// Sort rows by `col` (ascending unless `desc`), keeping the validity
/// mask aligned. Dead rows always order after live rows.
pub fn sort_by(batch: &ColumnBatch, col: &str, desc: bool) -> Result<ColumnBatch> {
    let c = batch.column(col)?;
    let mut idx: Vec<usize> = (0..batch.rows()).collect();
    idx.sort_by(|&a, &b| {
        match (batch.valid[a], batch.valid[b]) {
            (1, 0) => return std::cmp::Ordering::Less,
            (0, 1) => return std::cmp::Ordering::Greater,
            (0, 0) => return std::cmp::Ordering::Equal,
            _ => {}
        }
        let (x, y) = (c.get_f64(a), c.get_f64(b));
        let ord = x.partial_cmp(&y).unwrap_or(std::cmp::Ordering::Equal);
        if desc { ord.reverse() } else { ord }
    });
    Ok(ColumnBatch {
        schema: batch.schema.clone(),
        columns: batch.columns.iter().map(|cc| cc.take(&idx)).collect(),
        valid: idx.iter().map(|&i| batch.valid[i]).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("v"), Field::i32("tag")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![3.0, 1.0, 2.0]),
                Column::I32(vec![30, 10, 20]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn ascending_sort_aligns_columns() {
        let out = sort_by(&batch(), "v", false).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(out.column("tag").unwrap().as_i32().unwrap(), &[10, 20, 30]);
    }

    #[test]
    fn descending_sort() {
        let out = sort_by(&batch(), "v", true).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[3.0, 2.0, 1.0]);
    }

    #[test]
    fn dead_rows_sink() {
        let mut b = batch();
        b.valid[1] = 0; // kill the smallest value
        let out = sort_by(&b, "v", false).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[2.0, 3.0, 1.0]);
        assert_eq!(out.valid, vec![1, 1, 0]);
    }

    #[test]
    fn stable_for_equal_keys() {
        let schema = Schema::new(vec![Field::f32("v"), Field::i32("seq")]);
        let b = ColumnBatch::new(
            schema,
            vec![Column::F32(vec![1.0, 1.0, 1.0]), Column::I32(vec![0, 1, 2])],
        )
        .unwrap();
        let out = sort_by(&b, "v", false).unwrap();
        assert_eq!(out.column("seq").unwrap().as_i32().unwrap(), &[0, 1, 2]);
    }
}
