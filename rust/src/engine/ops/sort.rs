//! Sort by one column (stable; dead rows sink to the end).
//!
//! The sort key is extracted into a typed vector once (one dtype match
//! per kernel), so comparisons are plain f64 compares — no per-comparison
//! enum dispatch.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, Validity};
use crate::error::Result;
use std::cmp::Ordering;
use std::sync::Arc;

/// Sort rows by `col` (ascending unless `desc`), keeping the validity
/// mask aligned. Dead rows always order after live rows.
pub fn sort_by(batch: &ColumnBatch, col: &str, desc: bool) -> Result<ColumnBatch> {
    let c = batch.column(col)?;
    // Typed key extraction: dtype dispatched once, not per comparison.
    let keys: Vec<f64> = match c {
        Column::F32(v) => v.iter().map(|&x| x as f64).collect(),
        Column::I32(v) => v.iter().map(|&x| x as f64).collect(),
    };
    let cmp_keys = |a: usize, b: usize| {
        let ord = keys[a].partial_cmp(&keys[b]).unwrap_or(Ordering::Equal);
        if desc {
            ord.reverse()
        } else {
            ord
        }
    };
    let mut idx: Vec<usize> = (0..batch.rows()).collect();
    match batch.validity.mask() {
        None => idx.sort_by(|&a, &b| cmp_keys(a, b)),
        Some(mask) => idx.sort_by(|&a, &b| {
            match (mask[a] != 0, mask[b] != 0) {
                (true, false) => return Ordering::Less,
                (false, true) => return Ordering::Greater,
                (false, false) => return Ordering::Equal,
                (true, true) => {}
            }
            cmp_keys(a, b)
        }),
    }
    let validity = match batch.validity.mask() {
        None => Validity::all_live(batch.rows()),
        Some(mask) => Validity::from_mask(idx.iter().map(|&i| mask[i]).collect()),
    };
    Ok(ColumnBatch {
        schema: Arc::clone(&batch.schema),
        columns: batch.columns.iter().map(|cc| cc.take(&idx)).collect(),
        validity,
    })
}

/// Comparator shared by the single-batch kernel and the k-way merge:
/// dead rows order after live rows (and compare Equal among themselves,
/// so stability preserves their original order); live rows compare by
/// key, reversed for descending.
fn cmp_rows(a_live: bool, a_key: f64, b_live: bool, b_key: f64, desc: bool) -> Ordering {
    match (a_live, b_live) {
        (true, false) => Ordering::Less,
        (false, true) => Ordering::Greater,
        (false, false) => Ordering::Equal,
        (true, true) => {
            let ord = a_key.partial_cmp(&b_key).unwrap_or(Ordering::Equal);
            if desc {
                ord.reverse()
            } else {
                ord
            }
        }
    }
}

/// Chunked sort: **k-way merge over per-chunk sorted runs**. Each chunk
/// is index-sorted in place (keys extracted once, no per-chunk
/// materialization), then the runs merge directly into the single
/// output batch — ties take the earliest run, so the result is exactly
/// the stable global sort of the coalesced input (chunk order is row
/// order), pinned by `rust/tests/diff_chunked.rs`. The old
/// coalesce-then-sort path materialized the rows twice (the contiguous
/// staging copy, then the sorted gather); the merge materializes them
/// once, at the gather. Sorting still *outputs* one contiguous chunk —
/// it remains the explicit coalesce point downstream ops rely on, and
/// the planner/cost model charge the materialization through the op's
/// byte volume.
pub fn sort_chunks(batch: &ChunkedBatch, col: &str, desc: bool) -> Result<ChunkedBatch> {
    batch.schema().index_of(col)?;
    let chunks = batch.chunks();
    if chunks.len() <= 1 {
        // Zero/one chunk: coalesce is an O(1) clone (or empty) — the
        // single-batch kernel is already copy-minimal.
        return Ok(ChunkedBatch::from_batch(sort_by(&batch.coalesce(), col, desc)?));
    }

    // Per-run typed keys + liveness (dtype dispatched once per chunk).
    let keys: Vec<Vec<f64>> = chunks
        .iter()
        .map(|c| match c.column(col).expect("schema checked above") {
            Column::F32(v) => v.iter().map(|&x| x as f64).collect(),
            Column::I32(v) => v.iter().map(|&x| x as f64).collect(),
        })
        .collect();
    let masks: Vec<Option<&[u8]>> = chunks.iter().map(|c| c.validity.mask()).collect();
    let live = |r: usize, i: usize| match masks[r] {
        None => true,
        Some(m) => m[i] != 0,
    };

    // Sorted runs: per-chunk index sorts (stable, same comparator as
    // the single-batch kernel).
    let orders: Vec<Vec<usize>> = chunks
        .iter()
        .enumerate()
        .map(|(r, c)| {
            let mut idx: Vec<usize> = (0..c.rows()).collect();
            idx.sort_by(|&a, &b| {
                cmp_rows(live(r, a), keys[r][a], live(r, b), keys[r][b], desc)
            });
            idx
        })
        .collect();

    // K-way merge of the run fronts; strict-less keeps ties on the
    // earliest run (== global stable order). Linear front scan: chunk
    // counts are small (micro-batch assembly / window dataset counts),
    // so a heap would cost more than it saves.
    let mut pos = vec![0usize; chunks.len()];
    let mut picks: Vec<(usize, usize)> = Vec::with_capacity(batch.rows());
    loop {
        let mut best: Option<usize> = None;
        for r in 0..chunks.len() {
            if pos[r] >= orders[r].len() {
                continue;
            }
            match best {
                None => best = Some(r),
                Some(b) => {
                    let (ri, bi) = (orders[r][pos[r]], orders[b][pos[b]]);
                    if cmp_rows(live(r, ri), keys[r][ri], live(b, bi), keys[b][bi], desc)
                        == Ordering::Less
                    {
                        best = Some(r);
                    }
                }
            }
        }
        match best {
            Some(r) => {
                picks.push((r, orders[r][pos[r]]));
                pos[r] += 1;
            }
            None => break,
        }
    }

    // Single materialization: gather every column across the runs.
    let columns: Vec<Column> = (0..batch.schema().len())
        .map(|ci| match &chunks[0].columns[ci] {
            Column::F32(_) => {
                let slices: Vec<&[f32]> = chunks
                    .iter()
                    .map(|c| c.columns[ci].as_f32().expect("uniform chunk schemas"))
                    .collect();
                Column::F32(
                    picks.iter().map(|&(r, i)| slices[r][i]).collect::<Vec<f32>>().into(),
                )
            }
            Column::I32(_) => {
                let slices: Vec<&[i32]> = chunks
                    .iter()
                    .map(|c| c.columns[ci].as_i32().expect("uniform chunk schemas"))
                    .collect();
                Column::I32(
                    picks.iter().map(|&(r, i)| slices[r][i]).collect::<Vec<i32>>().into(),
                )
            }
        })
        .collect();
    let validity = if masks.iter().all(|m| m.is_none()) {
        Validity::all_live(picks.len())
    } else {
        Validity::from_mask(
            picks.iter().map(|&(r, i)| chunks[r].validity.get(i)).collect(),
        )
    };
    Ok(ChunkedBatch::from_batch(ColumnBatch {
        schema: Arc::clone(batch.schema()),
        columns,
        validity,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("v"), Field::i32("tag")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![3.0, 1.0, 2.0].into()),
                Column::I32(vec![30, 10, 20].into()),
            ],
        )
        .unwrap()
    }

    #[test]
    fn ascending_sort_aligns_columns() {
        let out = sort_by(&batch(), "v", false).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(out.column("tag").unwrap().as_i32().unwrap(), &[10, 20, 30]);
    }

    #[test]
    fn descending_sort() {
        let out = sort_by(&batch(), "v", true).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[3.0, 2.0, 1.0]);
    }

    #[test]
    fn dead_rows_sink() {
        let mut b = batch();
        b.validity.set_live(1, false); // kill the smallest value
        let out = sort_by(&b, "v", false).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[2.0, 3.0, 1.0]);
        assert_eq!(out.validity.to_vec(), vec![1, 1, 0]);
    }

    #[test]
    fn stable_for_equal_keys() {
        let schema = Schema::new(vec![Field::f32("v"), Field::i32("seq")]);
        let b = ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![1.0, 1.0, 1.0].into()),
                Column::I32(vec![0, 1, 2].into()),
            ],
        )
        .unwrap();
        let out = sort_by(&b, "v", false).unwrap();
        assert_eq!(out.column("seq").unwrap().as_i32().unwrap(), &[0, 1, 2]);
    }

    #[test]
    fn i32_sort_key() {
        let schema = Schema::new(vec![Field::i32("k")]);
        let b =
            ColumnBatch::new(schema, vec![Column::I32(vec![3, 1, 2].into())]).unwrap();
        let out = sort_by(&b, "k", false).unwrap();
        assert_eq!(out.column("k").unwrap().as_i32().unwrap(), &[1, 2, 3]);
    }

    fn tagged(vals: &[f32], first_tag: i32) -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("v"), Field::i32("tag")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::F32(vals.to_vec().into()),
                Column::I32(
                    (0..vals.len() as i32).map(|i| first_tag + i).collect::<Vec<i32>>().into(),
                ),
            ],
        )
        .unwrap()
    }

    #[test]
    fn kway_merge_equals_coalesced_sort() {
        // Three chunks with interleaved + duplicate keys, a dead row in
        // the middle chunk, both directions: the merge must match the
        // single-batch kernel over the coalesced rows bit for bit, and
        // emit one contiguous chunk (sort stays a coalesce point).
        let mut c = ChunkedBatch::from_batch(tagged(&[5.0, 1.0, 3.0], 0));
        let mut mid = tagged(&[3.0, 2.0, 9.0], 10);
        mid.validity.set_live(1, false);
        c.push(mid).unwrap();
        c.push(tagged(&[4.0, 3.0], 20)).unwrap();
        for desc in [false, true] {
            let merged = sort_chunks(&c, "v", desc).unwrap();
            let reference = sort_by(&c.coalesce(), "v", desc).unwrap();
            assert_eq!(merged.num_chunks(), 1, "sort must stay a coalesce point");
            assert_eq!(merged.coalesce(), reference, "desc={desc}");
        }
    }

    #[test]
    fn kway_merge_is_stable_across_chunks() {
        // Equal keys keep (chunk order, then within-chunk order): the
        // tag column pins the provenance of every duplicate.
        let mut c = ChunkedBatch::from_batch(tagged(&[1.0, 1.0], 0));
        c.push(tagged(&[1.0, 0.0], 10)).unwrap();
        c.push(tagged(&[1.0], 20)).unwrap();
        let out = sort_chunks(&c, "v", false).unwrap().coalesce();
        assert_eq!(out.column("tag").unwrap().as_i32().unwrap(), &[11, 0, 1, 10, 20]);
    }

    #[test]
    fn kway_merge_sinks_dead_rows_in_chunk_order() {
        let mut a = tagged(&[1.0, 9.0], 0);
        a.validity.set_live(1, false);
        let mut b = tagged(&[0.5, 2.0], 10);
        b.validity.set_live(0, false);
        let mut c = ChunkedBatch::from_batch(a);
        c.push(b).unwrap();
        let out = sort_chunks(&c, "v", false).unwrap().coalesce();
        // Live rows sorted first; dead rows trail in original order
        // (chunk 0's dead row before chunk 1's).
        assert_eq!(out.column("tag").unwrap().as_i32().unwrap(), &[0, 11, 1, 10]);
        assert_eq!(out.validity.to_vec(), vec![1, 1, 0, 0]);
    }

    #[test]
    fn single_chunk_sort_unchanged() {
        let c = ChunkedBatch::from_batch(batch());
        let out = sort_chunks(&c, "v", false).unwrap();
        assert_eq!(out.num_chunks(), 1);
        assert_eq!(
            out.coalesce().column("v").unwrap().as_f32().unwrap(),
            &[1.0, 2.0, 3.0]
        );
    }
}
