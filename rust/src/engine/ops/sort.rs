//! Sort by one column (stable; dead rows sink to the end).
//!
//! The sort key is extracted into a typed vector once (one dtype match
//! per kernel), so comparisons are plain f64 compares — no per-comparison
//! enum dispatch.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, Validity};
use crate::error::Result;
use std::cmp::Ordering;
use std::sync::Arc;

/// Sort rows by `col` (ascending unless `desc`), keeping the validity
/// mask aligned. Dead rows always order after live rows.
pub fn sort_by(batch: &ColumnBatch, col: &str, desc: bool) -> Result<ColumnBatch> {
    let c = batch.column(col)?;
    // Typed key extraction: dtype dispatched once, not per comparison.
    let keys: Vec<f64> = match c {
        Column::F32(v) => v.iter().map(|&x| x as f64).collect(),
        Column::I32(v) => v.iter().map(|&x| x as f64).collect(),
    };
    let cmp_keys = |a: usize, b: usize| {
        let ord = keys[a].partial_cmp(&keys[b]).unwrap_or(Ordering::Equal);
        if desc {
            ord.reverse()
        } else {
            ord
        }
    };
    let mut idx: Vec<usize> = (0..batch.rows()).collect();
    match batch.validity.mask() {
        None => idx.sort_by(|&a, &b| cmp_keys(a, b)),
        Some(mask) => idx.sort_by(|&a, &b| {
            match (mask[a] != 0, mask[b] != 0) {
                (true, false) => return Ordering::Less,
                (false, true) => return Ordering::Greater,
                (false, false) => return Ordering::Equal,
                (true, true) => {}
            }
            cmp_keys(a, b)
        }),
    }
    let validity = match batch.validity.mask() {
        None => Validity::all_live(batch.rows()),
        Some(mask) => Validity::from_mask(idx.iter().map(|&i| mask[i]).collect()),
    };
    Ok(ColumnBatch {
        schema: Arc::clone(&batch.schema),
        columns: batch.columns.iter().map(|cc| cc.take(&idx)).collect(),
        validity,
    })
}

/// Chunked sort. Sorting is the one CPU op whose output genuinely needs
/// a global contiguous view, so it is an **explicit coalesce point**:
/// the chunk list is materialized once, sorted, and returned as a single
/// chunk. The planner/cost model charge this materialization through the
/// op's byte volume.
pub fn sort_chunks(batch: &ChunkedBatch, col: &str, desc: bool) -> Result<ChunkedBatch> {
    batch.schema().index_of(col)?;
    Ok(ChunkedBatch::from_batch(sort_by(&batch.coalesce(), col, desc)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("v"), Field::i32("tag")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![3.0, 1.0, 2.0].into()),
                Column::I32(vec![30, 10, 20].into()),
            ],
        )
        .unwrap()
    }

    #[test]
    fn ascending_sort_aligns_columns() {
        let out = sort_by(&batch(), "v", false).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(out.column("tag").unwrap().as_i32().unwrap(), &[10, 20, 30]);
    }

    #[test]
    fn descending_sort() {
        let out = sort_by(&batch(), "v", true).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[3.0, 2.0, 1.0]);
    }

    #[test]
    fn dead_rows_sink() {
        let mut b = batch();
        b.validity.set_live(1, false); // kill the smallest value
        let out = sort_by(&b, "v", false).unwrap();
        assert_eq!(out.column("v").unwrap().as_f32().unwrap(), &[2.0, 3.0, 1.0]);
        assert_eq!(out.validity.to_vec(), vec![1, 1, 0]);
    }

    #[test]
    fn stable_for_equal_keys() {
        let schema = Schema::new(vec![Field::f32("v"), Field::i32("seq")]);
        let b = ColumnBatch::new(
            schema,
            vec![
                Column::F32(vec![1.0, 1.0, 1.0].into()),
                Column::I32(vec![0, 1, 2].into()),
            ],
        )
        .unwrap();
        let out = sort_by(&b, "v", false).unwrap();
        assert_eq!(out.column("seq").unwrap().as_i32().unwrap(), &[0, 1, 2]);
    }

    #[test]
    fn i32_sort_key() {
        let schema = Schema::new(vec![Field::i32("k")]);
        let b =
            ColumnBatch::new(schema, vec![Column::I32(vec![3, 1, 2].into())]).unwrap();
        let out = sort_by(&b, "k", false).unwrap();
        assert_eq!(out.column("k").unwrap().as_i32().unwrap(), &[1, 2, 3]);
    }
}
