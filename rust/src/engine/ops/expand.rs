//! Expand: replicate each live row `factor` times with a window-instance
//! tag — Spark's rewrite assigning rows of a sliding window to their
//! range/slide overlapping window instances.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, Field, Schema, Validity};
use crate::error::{Error, Result};
use std::sync::Arc;

/// Replicate rows `factor` times, appending an i32 `window_id` column
/// (0..factor) per replica.
pub fn expand(batch: &ColumnBatch, factor: usize) -> Result<ColumnBatch> {
    if factor == 0 {
        return Err(Error::Plan("expand factor must be >= 1".into()));
    }
    let rows = batch.rows();
    let mut idx = Vec::with_capacity(rows * factor);
    let mut wid = Vec::with_capacity(rows * factor);
    for w in 0..factor {
        for row in 0..rows {
            idx.push(row);
            wid.push(w as i32);
        }
    }
    let mut fields = batch.schema.fields.clone();
    fields.push(Field::i32("window_id"));
    let mut columns: Vec<Column> = batch.columns.iter().map(|c| c.take(&idx)).collect();
    columns.push(Column::I32(wid.into()));
    // Replicas of live rows are live: an all-live input yields an
    // all-live output without materializing a mask.
    let validity = match batch.validity.mask() {
        None => Validity::all_live(rows * factor),
        Some(mask) => Validity::from_mask(idx.iter().map(|&i| mask[i]).collect()),
    };
    Ok(ColumnBatch { schema: Schema::new(fields), columns, validity })
}

/// Chunked expand: emits one chunk per (window instance, input chunk) in
/// window-major order — the same global row order as the coalesced
/// kernel (`w0` rows, then `w1` rows, …) — but each replica *shares* the
/// input chunk's columns and only materializes the constant `window_id`
/// column, so the O(rows × factor) gather disappears.
pub fn expand_chunks(batch: &ChunkedBatch, factor: usize) -> Result<ChunkedBatch> {
    if factor == 0 {
        return Err(Error::Plan("expand factor must be >= 1".into()));
    }
    let mut fields = batch.schema().fields.clone();
    fields.push(Field::i32("window_id"));
    let schema = Schema::new(fields);
    let mut out = ChunkedBatch::new(Arc::clone(&schema));
    for w in 0..factor {
        for chunk in batch.chunks() {
            let mut columns = chunk.columns.clone();
            columns.push(Column::I32(vec![w as i32; chunk.rows()].into()));
            out.push(ColumnBatch {
                schema: Arc::clone(&schema),
                columns,
                validity: chunk.validity.clone(),
            })?;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("v")]);
        ColumnBatch::new(schema, vec![Column::F32(vec![1.0, 2.0].into())]).unwrap()
    }

    #[test]
    fn replicates_rows_with_window_ids() {
        let out = expand(&batch(), 3).unwrap();
        assert_eq!(out.rows(), 6);
        assert_eq!(
            out.column("window_id").unwrap().as_i32().unwrap(),
            &[0, 0, 1, 1, 2, 2]
        );
    }

    #[test]
    fn factor_one_is_tagging_only() {
        let out = expand(&batch(), 1).unwrap();
        assert_eq!(out.rows(), 2);
        assert_eq!(out.schema.len(), 2);
    }

    #[test]
    fn dead_rows_stay_dead_in_replicas() {
        let mut b = batch();
        b.validity.set_live(0, false);
        let out = expand(&b, 2).unwrap();
        assert_eq!(out.validity.to_vec(), vec![0, 1, 0, 1]);
    }

    #[test]
    fn zero_factor_rejected() {
        assert!(expand(&batch(), 0).is_err());
    }
}
