//! Scan: ingest-side operator.
//!
//! In the paper's Spark deployment, Scan parses CSV files (a GPU-preferred
//! operation, Table II). Our sources generate columnar data directly, so
//! the native Scan validates the batch against the expected schema and
//! compacts padding; the *cost* of parsing is charged by the device model
//! (bytes-proportional, GPU-leaning base cost 0.8).

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{ColumnBatch, Schema};
use crate::error::{Error, Result};
use std::sync::Arc;

/// Validate schema identity and pass rows through — zero-copy: the
/// returned batch shares every buffer with the input (O(1) Arc clones).
pub fn scan(batch: &ColumnBatch, expected: &Arc<Schema>) -> Result<ColumnBatch> {
    if batch.schema.as_ref() != expected.as_ref() {
        return Err(Error::Schema(format!(
            "scan schema mismatch: expected {:?}",
            expected.fields.iter().map(|f| &f.name).collect::<Vec<_>>()
        )));
    }
    Ok(batch.clone())
}

/// Chunked scan: one schema check, then an O(#chunks) Arc-clone of the
/// chunk list — no per-chunk work, no row copies.
pub fn scan_chunks(batch: &ChunkedBatch, expected: &Arc<Schema>) -> Result<ChunkedBatch> {
    if batch.schema().as_ref() != expected.as_ref() {
        return Err(Error::Schema(format!(
            "scan schema mismatch: expected {:?}",
            expected.fields.iter().map(|f| &f.name).collect::<Vec<_>>()
        )));
    }
    Ok(batch.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, Field};

    #[test]
    fn passes_matching_schema() {
        let schema = Schema::new(vec![Field::f32("x")]);
        let b = ColumnBatch::new(schema.clone(), vec![Column::F32(vec![1.0].into())])
            .unwrap();
        let out = scan(&b, &schema).unwrap();
        assert_eq!(out.rows(), 1);
        assert!(b.columns[0].shares_memory(&out.columns[0]), "scan is zero-copy");
    }

    #[test]
    fn rejects_mismatched_schema() {
        let schema = Schema::new(vec![Field::f32("x")]);
        let other = Schema::new(vec![Field::f32("y")]);
        let b = ColumnBatch::new(schema, vec![Column::F32(vec![1.0].into())]).unwrap();
        assert!(scan(&b, &other).is_err());
    }
}
