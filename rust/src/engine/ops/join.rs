//! Hash equi-join (inner): build on the right batch's key, probe the left.
//!
//! The LR1 self-join (`SegSpeedStr [range 30 slide 5] as A, SegSpeedStr as
//! L WHERE A.vehicle == L.vehicle`) probes the current micro-batch against
//! the window state snapshot.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, Field, Schema, Validity};
use crate::engine::ops::for_each_live_key;
use crate::error::Result;
use crate::util::hash::FxHashMap;
use std::sync::Arc;

/// Inner join: every (probe-row, matching build-row) pair, with build
/// columns appended under a `r_` prefix (self-join disambiguation).
/// Dead rows on either side never match.
pub fn hash_join(
    probe: &ColumnBatch,
    build: &ColumnBatch,
    probe_key: &str,
    build_key: &str,
) -> Result<ColumnBatch> {
    hash_join_pruned(probe, build, probe_key, build_key, None, None)
}

/// Join with projection pushdown: materialize only `keep_probe` probe
/// columns and `keep_build` build columns (`None` = all). The dominant
/// join cost is output materialization (|output| x |columns| gathers), so
/// pruning unreferenced columns is the §Perf L3 optimization for the LR1
/// self-join, which keeps only the probe side.
pub fn hash_join_pruned(
    probe: &ColumnBatch,
    build: &ColumnBatch,
    probe_key: &str,
    build_key: &str,
    keep_probe: Option<&[String]>,
    keep_build: Option<&[String]>,
) -> Result<ColumnBatch> {
    let pk = probe.column(probe_key)?;
    let bk = build.column(build_key)?;

    // Build side index: key -> row list (typed sweep, mask hoisted).
    let mut table: FxHashMap<i64, Vec<usize>> = FxHashMap::default();
    for_each_live_key(bk, &build.validity, |row, key| {
        table.entry(key).or_default().push(row);
    });

    // Probe: collect matching index pairs (pre-sized: the windowed
    // self-join typically amplifies; start at probe cardinality).
    let mut probe_idx = Vec::with_capacity(probe.rows());
    let mut build_idx = Vec::with_capacity(probe.rows());
    for_each_live_key(pk, &probe.validity, |row, key| {
        if let Some(matches) = table.get(&key) {
            for &b in matches {
                probe_idx.push(row);
                build_idx.push(b);
            }
        }
    });

    // Output schema: (kept) probe columns + prefixed (kept) build columns.
    let probe_sel: Vec<usize> = match keep_probe {
        None => (0..probe.schema.len()).collect(),
        Some(names) => names
            .iter()
            .map(|n| probe.schema.index_of(n))
            .collect::<Result<_>>()?,
    };
    let build_sel: Vec<usize> = match keep_build {
        None => (0..build.schema.len()).collect(),
        Some(names) => names
            .iter()
            .map(|n| build.schema.index_of(n))
            .collect::<Result<_>>()?,
    };
    let mut fields: Vec<Field> =
        probe_sel.iter().map(|&i| probe.schema.fields[i].clone()).collect();
    for &i in &build_sel {
        let f = &build.schema.fields[i];
        fields.push(Field { name: format!("r_{}", f.name), dtype: f.dtype });
    }
    // Materialization dominates join cost (output rows x columns random
    // gathers); fan the per-column gathers across cores (§Perf L3 log).
    let gathers: Vec<(&Column, &Vec<usize>)> = probe_sel
        .iter()
        .map(|&i| (&probe.columns[i], &probe_idx))
        .chain(build_sel.iter().map(|&i| (&build.columns[i], &build_idx)))
        .collect();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get().min(8))
        .unwrap_or(1);
    let columns: Vec<Column> = if probe_idx.len() * gathers.len() > 200_000 {
        crate::util::exec::par_map(gathers, threads, |_, (c, idx)| c.take(idx))
    } else {
        gathers.into_iter().map(|(c, idx)| c.take(idx)).collect()
    };
    Ok(ColumnBatch {
        schema: Schema::new(fields),
        columns,
        validity: Validity::all_live(probe_idx.len()),
    })
}

/// Chunked inner join: build the hash table across the build side's
/// chunk list (no window-state coalesce) and probe chunk by chunk,
/// emitting one output chunk per probe chunk. Build entries are inserted
/// in global (chunk-major) row order and probe chunks are traversed in
/// order, so the concatenated output is bit-identical to joining the
/// coalesced sides.
pub fn hash_join_chunks(
    probe: &ChunkedBatch,
    build: &ChunkedBatch,
    probe_key: &str,
    build_key: &str,
) -> Result<ChunkedBatch> {
    hash_join_chunks_pruned(probe, build, probe_key, build_key, None, None)
}

/// [`hash_join_chunks`] with projection pushdown (`None` = keep all).
pub fn hash_join_chunks_pruned(
    probe: &ChunkedBatch,
    build: &ChunkedBatch,
    probe_key: &str,
    build_key: &str,
    keep_probe: Option<&[String]>,
    keep_build: Option<&[String]>,
) -> Result<ChunkedBatch> {
    let pk_idx = probe.schema().index_of(probe_key)?;
    let bk_idx = build.schema().index_of(build_key)?;

    // Build-side index over the chunk list: key -> (chunk, row) in
    // global row order (chunk-major), matching the coalesced build scan.
    let mut table: FxHashMap<i64, Vec<(u32, u32)>> = FxHashMap::default();
    for (ci, chunk) in build.chunks().iter().enumerate() {
        for_each_live_key(&chunk.columns[bk_idx], &chunk.validity, |row, key| {
            table.entry(key).or_default().push((ci as u32, row as u32));
        });
    }

    // Output schema: (kept) probe columns + prefixed (kept) build columns.
    let probe_sel: Vec<usize> = match keep_probe {
        None => (0..probe.schema().len()).collect(),
        Some(names) => names
            .iter()
            .map(|n| probe.schema().index_of(n))
            .collect::<Result<_>>()?,
    };
    let build_sel: Vec<usize> = match keep_build {
        None => (0..build.schema().len()).collect(),
        Some(names) => names
            .iter()
            .map(|n| build.schema().index_of(n))
            .collect::<Result<_>>()?,
    };
    let mut fields: Vec<Field> =
        probe_sel.iter().map(|&i| probe.schema().fields[i].clone()).collect();
    for &i in &build_sel {
        let f = &build.schema().fields[i];
        fields.push(Field { name: format!("r_{}", f.name), dtype: f.dtype });
    }
    let out_schema = Schema::new(fields);

    // Materialization dominates join cost; the single-batch path fans
    // its per-column gathers across cores. Mirror that here — tiny
    // chunks must not serialize the probe path — at the same
    // work threshold: many chunks fan out chunk-wise (each task probes
    // and gathers one chunk), a lone big chunk fans out column-wise
    // (exactly the single-batch strategy); never both at once, so the
    // thread pool is not oversubscribed.
    let width = probe_sel.len() + build_sel.len();
    let threads = std::thread::available_parallelism()
        .map(|n| n.get().min(8))
        .unwrap_or(1);
    let chunk_parallel = threads > 1
        && probe.num_chunks() > 1
        && probe.rows() * width.max(1) > 200_000;

    // Probe + gather one chunk: deterministic and independent per probe
    // chunk (the shared hash table is read-only), producing at most one
    // output chunk. `fan_columns` spreads this chunk's gathers across
    // cores when the chunk itself carries enough work.
    let probe_one = |pchunk: &Arc<ColumnBatch>, fan_columns: bool| -> Option<ColumnBatch> {
        let mut probe_idx: Vec<usize> = Vec::new();
        let mut build_pairs: Vec<(u32, u32)> = Vec::new();
        for_each_live_key(&pchunk.columns[pk_idx], &pchunk.validity, |row, key| {
            if let Some(matches) = table.get(&key) {
                for &pair in matches {
                    probe_idx.push(row);
                    build_pairs.push(pair);
                }
            }
        });
        if probe_idx.is_empty() {
            return None;
        }
        enum Gather {
            Probe(usize),
            Build(usize),
        }
        let tasks: Vec<Gather> = probe_sel
            .iter()
            .map(|&i| Gather::Probe(i))
            .chain(build_sel.iter().map(|&i| Gather::Build(i)))
            .collect();
        let run = |t: &Gather| match *t {
            Gather::Probe(i) => pchunk.columns[i].take(&probe_idx),
            Gather::Build(i) => take_pairs(build.chunks(), i, &build_pairs),
        };
        let columns: Vec<Column> =
            if fan_columns && probe_idx.len() * tasks.len() > 200_000 {
                crate::util::exec::par_map(tasks, threads, |_, t| run(&t))
            } else {
                tasks.iter().map(run).collect()
            };
        Some(ColumnBatch {
            schema: Arc::clone(&out_schema),
            columns,
            validity: Validity::all_live(probe_idx.len()),
        })
    };

    let out_chunks: Vec<Option<ColumnBatch>> = if chunk_parallel {
        crate::util::exec::par_map(probe.chunks().to_vec(), threads, |_, chunk| {
            probe_one(&chunk, false)
        })
    } else {
        probe.chunks().iter().map(|c| probe_one(c, threads > 1)).collect()
    };

    let mut out = ChunkedBatch::new(Arc::clone(&out_schema));
    for chunk in out_chunks.into_iter().flatten() {
        out.push(chunk)?;
    }
    Ok(out)
}

/// Gather one column's values across a chunk list by (chunk, row) pairs
/// — the cross-chunk analog of [`Column::take`]. Dtype is dispatched
/// once (chunk schemas are uniform); only called with a non-empty pair
/// list, which implies the chunk list is non-empty.
fn take_pairs(chunks: &[Arc<ColumnBatch>], col: usize, pairs: &[(u32, u32)]) -> Column {
    match &chunks[0].columns[col] {
        Column::F32(_) => {
            let slices: Vec<&[f32]> = chunks
                .iter()
                .map(|c| c.columns[col].as_f32().expect("uniform chunk schemas"))
                .collect();
            Column::F32(
                pairs
                    .iter()
                    .map(|&(c, r)| slices[c as usize][r as usize])
                    .collect::<Vec<f32>>()
                    .into(),
            )
        }
        Column::I32(_) => {
            let slices: Vec<&[i32]> = chunks
                .iter()
                .map(|c| c.columns[col].as_i32().expect("uniform chunk schemas"))
                .collect();
            Column::I32(
                pairs
                    .iter()
                    .map(|&(c, r)| slices[c as usize][r as usize])
                    .collect::<Vec<i32>>()
                    .into(),
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn side(names: (&str, &str), keys: Vec<i32>, vals: Vec<f32>) -> ColumnBatch {
        let schema = Schema::new(vec![Field::i32(names.0), Field::f32(names.1)]);
        ColumnBatch::new(schema, vec![Column::I32(keys.into()), Column::F32(vals.into())])
            .unwrap()
    }

    #[test]
    fn inner_join_produces_all_pairs() {
        let probe = side(("k", "pv"), vec![1, 2, 3], vec![10.0, 20.0, 30.0]);
        let build = side(("k", "bv"), vec![2, 2, 9], vec![0.2, 0.22, 0.9]);
        let out = hash_join(&probe, &build, "k", "k").unwrap();
        assert_eq!(out.rows(), 2); // probe row `2` matches two build rows
        assert_eq!(out.column("pv").unwrap().as_f32().unwrap(), &[20.0, 20.0]);
        let bv: Vec<f32> = out.column("r_bv").unwrap().as_f32().unwrap().to_vec();
        assert_eq!(bv, vec![0.2, 0.22]);
    }

    #[test]
    fn dead_rows_do_not_match() {
        let mut probe = side(("k", "pv"), vec![1, 2], vec![1.0, 2.0]);
        let mut build = side(("k", "bv"), vec![1, 2], vec![0.1, 0.2]);
        probe.validity.set_live(0, false);
        build.validity.set_live(1, false);
        let out = hash_join(&probe, &build, "k", "k").unwrap();
        assert_eq!(out.rows(), 0);
    }

    #[test]
    fn no_matches_yields_empty() {
        let probe = side(("k", "pv"), vec![1], vec![1.0]);
        let build = side(("k", "bv"), vec![2], vec![0.2]);
        let out = hash_join(&probe, &build, "k", "k").unwrap();
        assert_eq!(out.rows(), 0);
        assert_eq!(out.schema.len(), 4);
    }

    #[test]
    fn pruned_join_materializes_subset() {
        let probe = side(("k", "pv"), vec![1, 2, 2], vec![1.0, 2.0, 3.0]);
        let build = side(("k", "bv"), vec![2, 2], vec![0.2, 0.25]);
        let keep_p = vec!["pv".to_string()];
        let keep_b: Vec<String> = vec![];
        let out = hash_join_pruned(&probe, &build, "k", "k", Some(&keep_p), Some(&keep_b))
            .unwrap();
        assert_eq!(out.rows(), 4); // 2 probe rows x 2 build matches
        assert_eq!(out.schema.len(), 1);
        assert_eq!(out.column("pv").unwrap().as_f32().unwrap(), &[2.0, 2.0, 3.0, 3.0]);
        // Row multiset identical to the unpruned join's pv column.
        let full = hash_join(&probe, &build, "k", "k").unwrap();
        assert_eq!(
            full.column("pv").unwrap().as_f32().unwrap(),
            out.column("pv").unwrap().as_f32().unwrap()
        );
    }

    #[test]
    fn pruned_join_unknown_column_errors() {
        let probe = side(("k", "pv"), vec![1], vec![1.0]);
        let keep = vec!["nope".to_string()];
        assert!(hash_join_pruned(&probe, &probe, "k", "k", Some(&keep), None).is_err());
    }

    #[test]
    fn parallel_chunked_probe_matches_single_batch_join() {
        // Enough rows x columns to cross the par_map threshold with many
        // tiny chunks: the fanned-out probe must stay bit-identical (in
        // row order) to the single-batch join over the coalesced sides.
        let chunk_rows = 2_000;
        let chunks = 30;
        let mut probe = ChunkedBatch::new(
            side(("k", "pv"), vec![], vec![]).schema,
        );
        for c in 0..chunks {
            let keys: Vec<i32> = (0..chunk_rows).map(|r| ((c * 7 + r) % 100) as i32).collect();
            let vals: Vec<f32> = (0..chunk_rows).map(|r| (c * chunk_rows + r) as f32).collect();
            probe.push(side(("k", "pv"), keys, vals)).unwrap();
        }
        let build_keys: Vec<i32> = (0..200).map(|r| (r % 100) as i32).collect();
        let build_vals: Vec<f32> = (0..200).map(|r| r as f32 / 10.0).collect();
        let build = ChunkedBatch::from_batch(side(("k", "bv"), build_keys, build_vals));

        let chunked = hash_join_chunks(&probe, &build, "k", "k").unwrap();
        let whole = hash_join(&probe.coalesce(), &build.coalesce(), "k", "k").unwrap();
        assert_eq!(chunked.rows(), whole.rows());
        assert_eq!(chunked.coalesce(), whole);
    }

    #[test]
    fn self_join_column_prefixing() {
        let b = side(("vehicle", "speed"), vec![7, 7], vec![55.0, 60.0]);
        let out = hash_join(&b, &b, "vehicle", "vehicle").unwrap();
        assert_eq!(out.rows(), 4); // 2x2 pairs
        assert!(out.column("r_vehicle").is_ok());
        assert!(out.column("r_speed").is_ok());
    }
}
