//! Native CPU operators (the "CPU execution function" of every query
//! operation, §II-A). The GPU counterparts are the AOT artifacts invoked
//! through [`crate::devices::gpu`]; both paths implement identical
//! semantics, which the integration tests assert against each other.

pub mod aggregate;
pub mod expand;
pub mod filter;
pub mod join;
pub mod project;
pub mod scan;
pub mod shuffle;
pub mod sort;

pub use aggregate::{AggFunc, AggSpec, hash_aggregate};
pub use expand::expand;
pub use filter::{Predicate, filter};
pub use join::hash_join;
pub use project::{project_affine, project_select};
pub use scan::scan;
pub use shuffle::shuffle;
pub use sort::sort_by;
