//! Native CPU operators (the "CPU execution function" of every query
//! operation, §II-A). The GPU counterparts are the AOT artifacts invoked
//! through [`crate::devices::gpu`]; both paths implement identical
//! semantics, which the integration tests assert against each other.

pub mod aggregate;
pub mod expand;
pub mod filter;
pub mod fused;
pub mod join;
pub mod project;
pub mod scan;
pub mod shuffle;
pub mod sort;

pub use aggregate::{AggFunc, AggSpec, hash_aggregate, hash_aggregate_chunks};
pub use expand::{expand, expand_chunks};
pub use filter::{Predicate, filter, filter_chunks};
pub use fused::{FusedAgg, FusedChainSpec, FusedStep};
pub use join::{hash_join, hash_join_chunks};
pub use project::{
    project_affine, project_affine_chunks, project_select, project_select_chunks,
};
pub use scan::{scan, scan_chunks};
pub use shuffle::{shuffle, shuffle_chunks};
pub use sort::{sort_by, sort_chunks};

use crate::engine::column::{Column, Validity};

/// Visit every live row's key as canonical i64 bits (i32 widened, f32 by
/// bit pattern — the hash/equality encoding the join, shuffle and
/// aggregate kernels share). The dtype is matched once per call and the
/// validity mask hoisted out of the loop: typed straight-line sweeps, no
/// per-row enum dispatch.
pub(crate) fn for_each_live_key(
    col: &Column,
    validity: &Validity,
    mut f: impl FnMut(usize, i64),
) {
    match (col, validity.mask()) {
        (Column::I32(v), None) => {
            for (row, &x) in v.iter().enumerate() {
                f(row, x as i64);
            }
        }
        (Column::I32(v), Some(mask)) => {
            for (row, (&x, &m)) in v.iter().zip(mask).enumerate() {
                if m != 0 {
                    f(row, x as i64);
                }
            }
        }
        (Column::F32(v), None) => {
            for (row, &x) in v.iter().enumerate() {
                f(row, x.to_bits() as i64);
            }
        }
        (Column::F32(v), Some(mask)) => {
            for (row, (&x, &m)) in v.iter().zip(mask).enumerate() {
                if m != 0 {
                    f(row, x.to_bits() as i64);
                }
            }
        }
    }
}
