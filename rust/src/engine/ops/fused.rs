//! Fused single-pass execution of scan→filter→project→(aggregate)
//! chains: one typed traversal per chunk instead of one full pass per
//! operator.
//!
//! The staged pipeline pays per op: filter writes a fresh `Validity`
//! mask, project re-wraps every chunk, aggregate sweeps the mask a third
//! time. A [`FusedChainSpec`] runs the whole chain in one traversal —
//! per chunk, the affine columns are computed (over *all* rows, exactly
//! as the staged kernels do), every filter predicate is ANDed into **one
//! mask scratch** (no intermediate `Validity` materialization between
//! members), and either the output columns are gathered (shared input
//! buffers + the freshly computed affines) or the rows are fed straight
//! into the group table of a terminal aggregate.
//!
//! # Output invariance
//!
//! Fused execution is bit-identical to running the member ops one at a
//! time — same column bits (f32 compared by `to_bits`), same validity,
//! same schema, same chunk layout (aggregation still materializes one
//! fresh chunk), and the same errors in the same member order. The
//! differential harness (`rust/tests/diff_chunked.rs`) pins this across
//! arbitrary pipelines × chunk layouts.
//!
//! # Chunk pruning
//!
//! When a chunk's per-column min/max bounds prove a filter predicate
//! cannot match ([`Predicate::can_match`]), the per-row sweeps are
//! skipped: the chunk contributes an all-dead mask (exactly what
//! evaluating every row would have produced), and an aggregate-tail
//! chain skips the chunk's affine compute and group-table feed entirely.
//! Bounds come from encoded blocks ([`crate::engine::encode`]) via
//! [`run_chunks_with_stats`]; aggregate-tail chains additionally compute
//! the bound inline for plain chunks (one cheap min/max sweep buys
//! skipping the whole chunk). Only plain (non-aggregate) chains without
//! provided stats never prune — there the stats sweep would cost as
//! much as the predicate sweep it replaces.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, DType, Field, Schema, Validity};
use crate::engine::encode::{column_stats, ChunkStats};
use crate::engine::ops::aggregate::{AggFunc, AggSpec};
use crate::engine::ops::filter::Predicate;
use crate::error::{Error, Result};
use crate::util::hash::FxHashMap;
use std::sync::Arc;

/// One fusable member op (the engine-level mirror of the fusable
/// `OpSpec` kinds; `query/fuse.rs` does the translation).
#[derive(Clone, Debug)]
pub enum FusedStep {
    /// Source scan — identity over the chunk list.
    Scan,
    Filter { col: String, pred: Predicate },
    Select { keep: Vec<String> },
    Affine { a: String, b: String, alpha: f32, beta: f32, out: String },
}

/// Terminal aggregate of a fused chain.
#[derive(Clone, Debug)]
pub struct FusedAgg {
    pub group: Vec<String>,
    pub aggs: Vec<AggSpec>,
    pub having: Option<(String, Predicate)>,
}

/// A fused chain: member steps in op order plus an optional terminal
/// aggregate.
#[derive(Clone, Debug)]
pub struct FusedChainSpec {
    pub steps: Vec<FusedStep>,
    pub agg: Option<FusedAgg>,
}

/// Where a virtual column's data lives: an input column of the chain's
/// source batch, or the k-th affine column the chain computes.
#[derive(Clone, Copy, Debug)]
enum Prov {
    Input(usize),
    Computed(usize),
}

#[derive(Clone, Copy, Debug)]
struct AffineExpr {
    a: Prov,
    b: Prov,
    alpha: f32,
    beta: f32,
}

struct CompiledAgg {
    key: Vec<Prov>,
    key_fields: Vec<Field>,
    /// Per agg: the value column's provenance (`None` for COUNT).
    vals: Vec<Option<Prov>>,
    aggs: Vec<AggSpec>,
    having: Option<(String, Predicate)>,
}

/// The chain resolved against a concrete input schema: every name
/// lookup and dtype check done once, in member order (so errors surface
/// exactly as staged execution would raise them).
struct Compiled {
    filters: Vec<(Prov, Predicate)>,
    computed: Vec<AffineExpr>,
    /// Provenance of the (pre-aggregate) output columns.
    output: Vec<Prov>,
    /// Schema of the (pre-aggregate) output.
    out_schema: Arc<Schema>,
    agg: Option<CompiledAgg>,
}

fn resolve(cur: &[(Field, Prov)], name: &str) -> Result<usize> {
    cur.iter()
        .position(|(f, _)| f.name == name)
        .ok_or_else(|| Error::Schema(format!("unknown column `{name}`")))
}

fn compile(in_schema: &Schema, spec: &FusedChainSpec) -> Result<Compiled> {
    // The evolving virtual schema: (field, where-the-data-lives).
    let mut cur: Vec<(Field, Prov)> = in_schema
        .fields
        .iter()
        .enumerate()
        .map(|(i, f)| (f.clone(), Prov::Input(i)))
        .collect();
    let mut filters = Vec::new();
    let mut computed: Vec<AffineExpr> = Vec::new();
    for step in &spec.steps {
        match step {
            FusedStep::Scan => {}
            FusedStep::Filter { col, pred } => {
                let i = resolve(&cur, col)?;
                filters.push((cur[i].1, *pred));
            }
            FusedStep::Select { keep } => {
                let mut next = Vec::with_capacity(keep.len());
                for name in keep {
                    let i = resolve(&cur, name)?;
                    next.push(cur[i].clone());
                }
                cur = next;
            }
            FusedStep::Affine { a, b, alpha, beta, out } => {
                let ai = resolve(&cur, a)?;
                let bi = resolve(&cur, b)?;
                if cur[ai].0.dtype != DType::F32 || cur[bi].0.dtype != DType::F32 {
                    return Err(Error::Schema("expected f32 column".into()));
                }
                let k = computed.len();
                computed.push(AffineExpr {
                    a: cur[ai].1,
                    b: cur[bi].1,
                    alpha: *alpha,
                    beta: *beta,
                });
                cur.push((Field::f32(out), Prov::Computed(k)));
            }
        }
    }
    let agg = match &spec.agg {
        None => None,
        Some(a) => {
            if a.group.is_empty() {
                return Err(Error::Plan("aggregate needs at least one group column".into()));
            }
            let mut key = Vec::with_capacity(a.group.len());
            let mut key_fields = Vec::with_capacity(a.group.len());
            for name in &a.group {
                let i = resolve(&cur, name)?;
                key.push(cur[i].1);
                key_fields.push(cur[i].0.clone());
            }
            let vals = a
                .aggs
                .iter()
                .map(|s| {
                    if s.func == AggFunc::Count {
                        Ok(None)
                    } else {
                        let i = resolve(&cur, &s.value_col)?;
                        if cur[i].0.dtype != DType::F32 {
                            return Err(Error::Schema("expected f32 column".into()));
                        }
                        Ok(Some(cur[i].1))
                    }
                })
                .collect::<Result<_>>()?;
            Some(CompiledAgg {
                key,
                key_fields,
                vals,
                aggs: a.aggs.clone(),
                having: a.having.clone(),
            })
        }
    };
    let (out_fields, output): (Vec<Field>, Vec<Prov>) = cur.into_iter().unzip();
    Ok(Compiled { filters, computed, output, out_schema: Schema::new(out_fields), agg })
}

/// Typed view of one virtual column within a chunk.
enum ColRef<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

fn col_ref<'a>(chunk: &'a ColumnBatch, computed: &'a [Column], prov: Prov) -> ColRef<'a> {
    let col = match prov {
        Prov::Input(i) => &chunk.columns[i],
        Prov::Computed(k) => &computed[k],
    };
    match col {
        Column::F32(v) => ColRef::F32(v.as_slice()),
        Column::I32(v) => ColRef::I32(v.as_slice()),
    }
}

fn col_f32<'a>(chunk: &'a ColumnBatch, computed: &'a [Column], prov: Prov) -> &'a [f32] {
    match prov {
        Prov::Input(i) => chunk.columns[i].as_f32().expect("dtype checked at compile"),
        Prov::Computed(k) => computed[k].as_f32().expect("computed columns are f32"),
    }
}

/// One typed predicate sweep ANDed into the shared mask scratch
/// (the fused analog of `filter::apply_pred`); returns the surviving
/// live count.
fn sweep(vals: ColRef<'_>, mask: &mut [u8], pred: Predicate) -> usize {
    fn go<T: Copy>(vals: &[T], mask: &mut [u8], pred: Predicate, to: impl Fn(T) -> f64) -> usize {
        let mut live = 0usize;
        for (m, &x) in mask.iter_mut().zip(vals) {
            *m &= pred.eval(to(x)) as u8;
            live += *m as usize;
        }
        live
    }
    match vals {
        ColRef::F32(v) => go(v, mask, pred, |x| x as f64),
        ColRef::I32(v) => go(v, mask, pred, |x| x as f64),
    }
}

/// Compute every affine column of the chain for one chunk (over *all*
/// rows — dead included — exactly like the staged kernel).
fn compute_affines(chunk: &ColumnBatch, exprs: &[AffineExpr]) -> Vec<Column> {
    let mut out: Vec<Column> = Vec::with_capacity(exprs.len());
    for e in exprs {
        let vals: Vec<f32> = {
            let a = col_f32(chunk, &out, e.a);
            let b = col_f32(chunk, &out, e.b);
            a.iter().zip(b).map(|(x, y)| e.alpha * x + e.beta * y).collect()
        };
        out.push(Column::F32(vals.into()));
    }
    out
}

/// Is this chunk provably all-dead under the chain's filters?
/// `provided` is the chunk's stats when known (encoded blocks);
/// `compute_inline` additionally derives the bound from the plain
/// column (worth it only when pruning skips real work — aggregate
/// tails). Bounds exist only for input-provenance filter columns;
/// computed columns never prune.
fn prunable(
    chunk: &ColumnBatch,
    filters: &[(Prov, Predicate)],
    provided: Option<&ChunkStats>,
    compute_inline: bool,
) -> bool {
    if chunk.rows() == 0 {
        return false;
    }
    for (prov, pred) in filters {
        let Prov::Input(i) = prov else { continue };
        let bound = match provided.and_then(|s| s.per_col.get(*i).copied().flatten()) {
            Some(b) => Some(b),
            None if compute_inline => column_stats(&chunk.columns[*i]),
            None => None,
        };
        if let Some((lo, hi)) = bound {
            if !pred.can_match(lo, hi) {
                return true;
            }
        }
    }
    false
}

/// Execute a fused chain over `batch` with no external stats: pruning
/// fires only for aggregate-tail chains (inline bounds). Returns the
/// result and the number of pruned chunks.
pub fn run_chunks(batch: &ChunkedBatch, spec: &FusedChainSpec) -> Result<(ChunkedBatch, usize)> {
    run_chunks_with_stats(batch, spec, &[])
}

/// Execute a fused chain with per-chunk min/max bounds supplied by the
/// caller (index-aligned with `batch.chunks()`; missing/`None` entries
/// mean "unknown"). Returns the result and the pruned-chunk count.
pub fn run_chunks_with_stats(
    batch: &ChunkedBatch,
    spec: &FusedChainSpec,
    stats: &[Option<ChunkStats>],
) -> Result<(ChunkedBatch, usize)> {
    let compiled = compile(batch.schema(), spec)?;
    match &compiled.agg {
        None => run_projection(batch, &compiled, stats),
        Some(_) => run_aggregate(batch, &compiled, stats),
    }
}

/// Non-aggregate tail: one output chunk per input chunk — shared input
/// buffers, fresh affine columns, one mask scratch for the whole chain.
fn run_projection(
    batch: &ChunkedBatch,
    compiled: &Compiled,
    stats: &[Option<ChunkStats>],
) -> Result<(ChunkedBatch, usize)> {
    let mut out = ChunkedBatch::new(Arc::clone(&compiled.out_schema));
    let mut pruned_chunks = 0usize;
    for (ci, chunk) in batch.chunks().iter().enumerate() {
        let computed = compute_affines(chunk, &compiled.computed);
        let validity = if compiled.filters.is_empty() {
            chunk.validity.clone()
        } else {
            let provided = stats.get(ci).and_then(|s| s.as_ref());
            if prunable(chunk, &compiled.filters, provided, false) {
                // Every row fails some filter: the sweeps would have
                // zeroed the whole mask (input-dead rows included).
                pruned_chunks += 1;
                Validity::from_parts_counted(vec![0u8; chunk.rows()], 0)
            } else {
                let mut mask = chunk.validity.to_vec();
                let mut live = chunk.live_rows();
                for (prov, pred) in &compiled.filters {
                    live = sweep(col_ref(chunk, &computed, *prov), &mut mask, *pred);
                }
                Validity::from_parts_counted(mask, live)
            }
        };
        let columns: Vec<Column> = compiled
            .output
            .iter()
            .map(|p| match p {
                Prov::Input(i) => chunk.columns[*i].clone(),
                Prov::Computed(k) => computed[*k].clone(),
            })
            .collect();
        out.push(ColumnBatch {
            schema: Arc::clone(&compiled.out_schema),
            columns,
            validity,
        })?;
    }
    Ok((out, pruned_chunks))
}

/// Aggregate tail: the group table is fed chunk by chunk in order
/// (identical accumulation to `aggregate::hash_aggregate_parts`, so
/// first-appearance group order — and every f64 rounding step — matches
/// the staged path bit for bit). Pruned chunks skip everything.
fn run_aggregate(
    batch: &ChunkedBatch,
    compiled: &Compiled,
    stats: &[Option<ChunkStats>],
) -> Result<(ChunkedBatch, usize)> {
    let agg = compiled.agg.as_ref().expect("aggregate tail");
    let mut slots: FxHashMap<Vec<i64>, usize> = FxHashMap::default();
    let mut order: Vec<Vec<i64>> = Vec::new();
    let mut sums: Vec<Vec<f64>> = Vec::new();
    let mut counts: Vec<f64> = Vec::new();
    let mut key: Vec<i64> = Vec::with_capacity(agg.key.len());
    let mut pruned_chunks = 0usize;
    for (ci, chunk) in batch.chunks().iter().enumerate() {
        let provided = stats.get(ci).and_then(|s| s.as_ref());
        if !compiled.filters.is_empty()
            && prunable(chunk, &compiled.filters, provided, true)
        {
            // All rows dead: nothing reaches the group table, and the
            // affine compute + sweeps can be skipped wholesale.
            pruned_chunks += 1;
            continue;
        }
        let computed = compute_affines(chunk, &compiled.computed);
        // The chain's single mask scratch; `None` = all input rows live
        // and no filters (the staged no-mask fast path).
        let fused_mask: Option<Vec<u8>> = if compiled.filters.is_empty() {
            chunk.validity.mask().map(|m| m.to_vec())
        } else {
            let mut mask = chunk.validity.to_vec();
            let mut live = chunk.live_rows();
            for (prov, pred) in &compiled.filters {
                live = sweep(col_ref(chunk, &computed, *prov), &mut mask, *pred);
            }
            if live == chunk.rows() {
                None
            } else {
                Some(mask)
            }
        };
        let key_cols: Vec<ColRef<'_>> =
            agg.key.iter().map(|&p| col_ref(chunk, &computed, p)).collect();
        let value_cols: Vec<Option<&[f32]>> = agg
            .vals
            .iter()
            .map(|v| v.map(|p| col_f32(chunk, &computed, p)))
            .collect();
        let mask = fused_mask.as_deref();
        for row in 0..chunk.rows() {
            if let Some(m) = mask {
                if m[row] == 0 {
                    continue;
                }
            }
            key.clear();
            for kc in &key_cols {
                key.push(match kc {
                    ColRef::I32(v) => v[row] as i64,
                    ColRef::F32(v) => v[row].to_bits() as i64,
                });
            }
            let slot = match slots.get(&key) {
                Some(&s) => s,
                None => {
                    let s = order.len();
                    slots.insert(key.clone(), s);
                    order.push(key.clone());
                    sums.push(vec![0.0; agg.aggs.len()]);
                    counts.push(0.0);
                    s
                }
            };
            counts[slot] += 1.0;
            for (ai, vc) in value_cols.iter().enumerate() {
                if let Some(vals) = vc {
                    sums[slot][ai] += vals[row] as f64;
                }
            }
        }
    }
    // Output assembly — the same shape as the staged aggregate.
    let mut fields = agg.key_fields.clone();
    for a in &agg.aggs {
        fields.push(Field::f32(&a.out));
    }
    let n_groups = order.len();
    let mut columns: Vec<Column> = Vec::with_capacity(fields.len());
    for (k, f) in agg.key_fields.iter().enumerate() {
        match f.dtype {
            DType::I32 => columns.push(Column::I32(
                order.iter().map(|key| key[k] as i32).collect::<Vec<i32>>().into(),
            )),
            DType::F32 => columns.push(Column::F32(
                order
                    .iter()
                    .map(|key| f32::from_bits(key[k] as u32))
                    .collect::<Vec<f32>>()
                    .into(),
            )),
        }
    }
    for (ai, a) in agg.aggs.iter().enumerate() {
        let vals: Vec<f32> = (0..n_groups)
            .map(|g| match a.func {
                AggFunc::Sum => sums[g][ai] as f32,
                AggFunc::Count => counts[g] as f32,
                AggFunc::Avg => (sums[g][ai] / counts[g].max(1.0)) as f32,
            })
            .collect();
        columns.push(Column::F32(vals.into()));
    }
    let mut out = ColumnBatch {
        schema: Schema::new(fields),
        columns,
        validity: Validity::all_live(n_groups),
    };
    if let Some((col, pred)) = &agg.having {
        out = crate::engine::ops::filter::filter(&out, col, *pred)?;
    }
    Ok((ChunkedBatch::from_batch(out), pruned_chunks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ops;

    fn batch(rows: usize) -> ColumnBatch {
        let schema = Schema::new(vec![
            Field::f32("v"),
            Field::f32("w"),
            Field::i32("k"),
        ]);
        ColumnBatch::new(
            schema,
            vec![
                Column::F32((0..rows).map(|i| i as f32).collect::<Vec<_>>().into()),
                Column::F32((0..rows).map(|i| (i as f32) * 0.5).collect::<Vec<_>>().into()),
                Column::I32((0..rows).map(|i| (i % 4) as i32).collect::<Vec<_>>().into()),
            ],
        )
        .unwrap()
    }

    fn layout(b: &ColumnBatch, cuts: &[usize]) -> ChunkedBatch {
        let mut out = ChunkedBatch::new(Arc::clone(&b.schema));
        let mut prev = 0;
        for &c in cuts {
            out.push(b.slice(prev, c - prev)).unwrap();
            prev = c;
        }
        out.push(b.slice(prev, b.rows() - prev)).unwrap();
        out
    }

    /// Staged reference: run the members one op at a time.
    fn staged(b: &ChunkedBatch, spec: &FusedChainSpec) -> Result<ChunkedBatch> {
        let mut cur = b.clone();
        for s in &spec.steps {
            cur = match s {
                FusedStep::Scan => cur.clone(),
                FusedStep::Filter { col, pred } => ops::filter_chunks(&cur, col, *pred)?,
                FusedStep::Select { keep } => {
                    let names: Vec<&str> = keep.iter().map(|s| s.as_str()).collect();
                    ops::project_select_chunks(&cur, &names)?
                }
                FusedStep::Affine { a, b, alpha, beta, out } => {
                    ops::project_affine_chunks(&cur, a, b, *alpha, *beta, out)?
                }
            };
        }
        if let Some(a) = &spec.agg {
            let groups: Vec<&str> = a.group.iter().map(|s| s.as_str()).collect();
            let hv = a.having.as_ref().map(|(c, p)| (c.as_str(), *p));
            cur = ops::hash_aggregate_chunks(&cur, &groups, &a.aggs, hv)?;
        }
        Ok(cur)
    }

    fn chain() -> FusedChainSpec {
        FusedChainSpec {
            steps: vec![
                FusedStep::Scan,
                FusedStep::Filter { col: "v".into(), pred: Predicate::Ge(3.0) },
                FusedStep::Affine {
                    a: "v".into(),
                    b: "w".into(),
                    alpha: 2.0,
                    beta: -1.0,
                    out: "mix".into(),
                },
                FusedStep::Select { keep: vec!["mix".into(), "k".into()] },
            ],
            agg: None,
        }
    }

    #[test]
    fn fused_matches_staged_projection_chain() {
        let b = batch(17);
        let chunks = layout(&b, &[4, 9]);
        let (fused, pruned) = run_chunks(&chunks, &chain()).unwrap();
        assert_eq!(fused, staged(&chunks, &chain()).unwrap());
        assert_eq!(fused.num_chunks(), 3, "chunk layout preserved");
        assert_eq!(pruned, 0);
    }

    #[test]
    fn fused_matches_staged_aggregate_chain() {
        let mut spec = chain();
        spec.agg = Some(FusedAgg {
            group: vec!["k".into()],
            aggs: vec![AggSpec::sum("mix", "s"), AggSpec::count("c")],
            having: Some(("c".into(), Predicate::Ge(2.0))),
        });
        let b = batch(23);
        let chunks = layout(&b, &[5, 11, 16]);
        let (fused, _) = run_chunks(&chunks, &spec).unwrap();
        let reference = staged(&chunks, &spec).unwrap();
        assert_eq!(fused, reference);
        assert_eq!(fused.num_chunks(), 1, "aggregate materializes one chunk");
    }

    #[test]
    fn aggregate_tail_prunes_dead_chunks_inline() {
        let mut spec = FusedChainSpec {
            steps: vec![
                FusedStep::Scan,
                // Rows 0..16: only the last chunk (12..) can match.
                FusedStep::Filter { col: "v".into(), pred: Predicate::Ge(12.0) },
            ],
            agg: None,
        };
        spec.agg = Some(FusedAgg {
            group: vec!["k".into()],
            aggs: vec![AggSpec::count("c")],
            having: None,
        });
        let b = batch(16);
        let chunks = layout(&b, &[6, 12]);
        let (fused, pruned) = run_chunks(&chunks, &spec).unwrap();
        assert_eq!(pruned, 2, "both all-dead chunks pruned");
        assert_eq!(fused, staged(&chunks, &spec).unwrap());
    }

    #[test]
    fn provided_stats_prune_projection_chunks() {
        let spec = FusedChainSpec {
            steps: vec![
                FusedStep::Scan,
                FusedStep::Filter { col: "v".into(), pred: Predicate::Lt(4.0) },
            ],
            agg: None,
        };
        let b = batch(12);
        let chunks = layout(&b, &[4, 8]);
        let stats: Vec<Option<ChunkStats>> =
            chunks.chunks().iter().map(|c| Some(ChunkStats::of(c))).collect();
        let (fused, pruned) = run_chunks_with_stats(&chunks, &spec, &stats).unwrap();
        assert_eq!(pruned, 2, "chunks [4,8) and [8,12) fail v < 4");
        assert_eq!(fused, staged(&chunks, &spec).unwrap());
        // Without stats, projection chains never prune (no win to buy).
        let (same, none) = run_chunks(&chunks, &spec).unwrap();
        assert_eq!(none, 0);
        assert_eq!(same, fused);
    }

    #[test]
    fn errors_match_staged_member_order() {
        let b = batch(5);
        let chunks = layout(&b, &[2]);
        // Unknown filter column.
        let bad = FusedChainSpec {
            steps: vec![FusedStep::Scan, FusedStep::Filter {
                col: "nope".into(),
                pred: Predicate::Ge(0.0),
            }],
            agg: None,
        };
        assert_eq!(
            run_chunks(&chunks, &bad).unwrap_err().to_string(),
            staged(&chunks, &bad).unwrap_err().to_string()
        );
        // Affine over an i32 column.
        let bad = FusedChainSpec {
            steps: vec![FusedStep::Scan, FusedStep::Affine {
                a: "k".into(),
                b: "v".into(),
                alpha: 1.0,
                beta: 1.0,
                out: "x".into(),
            }],
            agg: None,
        };
        assert_eq!(
            run_chunks(&chunks, &bad).unwrap_err().to_string(),
            staged(&chunks, &bad).unwrap_err().to_string()
        );
        // A select that drops the column a later member needs.
        let bad = FusedChainSpec {
            steps: vec![
                FusedStep::Scan,
                FusedStep::Select { keep: vec!["k".into()] },
                FusedStep::Filter { col: "v".into(), pred: Predicate::Ge(0.0) },
            ],
            agg: None,
        };
        assert_eq!(
            run_chunks(&chunks, &bad).unwrap_err().to_string(),
            staged(&chunks, &bad).unwrap_err().to_string()
        );
        // Empty group list on the aggregate tail.
        let bad = FusedChainSpec {
            steps: vec![FusedStep::Scan],
            agg: Some(FusedAgg { group: vec![], aggs: vec![AggSpec::count("c")], having: None }),
        };
        assert_eq!(
            run_chunks(&chunks, &bad).unwrap_err().to_string(),
            staged(&chunks, &bad).unwrap_err().to_string()
        );
    }

    #[test]
    fn empty_chunk_list_matches_staged() {
        let b = batch(0);
        let empty = ChunkedBatch::new(Arc::clone(&b.schema));
        let (fused, _) = run_chunks(&empty, &chain()).unwrap();
        assert_eq!(fused, staged(&empty, &chain()).unwrap());
        assert_eq!(fused.num_chunks(), 0);
        // Aggregate over nothing still materializes its one empty chunk.
        let mut spec = chain();
        spec.agg = Some(FusedAgg {
            group: vec!["k".into()],
            aggs: vec![AggSpec::count("c")],
            having: None,
        });
        let (fused, _) = run_chunks(&empty, &spec).unwrap();
        assert_eq!(fused.num_chunks(), 1);
        assert_eq!(fused, staged(&empty, &spec).unwrap());
    }

    #[test]
    fn affine_may_reference_earlier_affine_output() {
        let spec = FusedChainSpec {
            steps: vec![
                FusedStep::Scan,
                FusedStep::Affine {
                    a: "v".into(),
                    b: "w".into(),
                    alpha: 1.0,
                    beta: 1.0,
                    out: "s1".into(),
                },
                FusedStep::Affine {
                    a: "s1".into(),
                    b: "v".into(),
                    alpha: 0.5,
                    beta: 2.0,
                    out: "s2".into(),
                },
                FusedStep::Filter { col: "s2".into(), pred: Predicate::Ge(5.0) },
            ],
            agg: None,
        };
        let b = batch(11);
        let chunks = layout(&b, &[3, 7]);
        let (fused, _) = run_chunks(&chunks, &spec).unwrap();
        assert_eq!(fused, staged(&chunks, &spec).unwrap());
    }

    #[test]
    fn dead_input_rows_stay_dead_and_shared_buffers_stay_shared() {
        let mut b = batch(9);
        b.validity.set_live(4, false);
        let chunks = layout(&b, &[3]);
        let spec = FusedChainSpec {
            steps: vec![
                FusedStep::Scan,
                FusedStep::Filter { col: "v".into(), pred: Predicate::Ge(1.0) },
                FusedStep::Select { keep: vec!["v".into(), "k".into()] },
            ],
            agg: None,
        };
        let (fused, _) = run_chunks(&chunks, &spec).unwrap();
        assert_eq!(fused, staged(&chunks, &spec).unwrap());
        // Selected columns alias the input chunks — fusion adds no copies.
        assert!(fused.chunks()[0].columns[0]
            .shares_memory(&chunks.chunks()[0].columns[0]));
    }
}
