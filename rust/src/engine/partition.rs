//! Partitioning a micro-batch across `NumCores` data partitions.
//!
//! "Generally, the number of data partitions is the same as the number of
//! CPU cores used per application" (§II-A); MapDevice's cost models run on
//! the *partition* size, not the micro-batch size (§III-D).

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::ColumnBatch;

/// One data partition with its wire-size share (`Part_(i,j)` in Table I).
#[derive(Clone, Debug)]
pub struct Partition {
    pub index: usize,
    pub batch: ColumnBatch,
    pub wire_bytes: usize,
}

/// [`Partition`] over the chunked execution representation.
#[derive(Clone, Debug)]
pub struct ChunkedPartition {
    pub index: usize,
    pub batch: ChunkedBatch,
    pub wire_bytes: usize,
}

/// Split `batch` into `n` contiguous row chunks, distributing the
/// remainder one row at a time (sizes differ by at most one row).
/// `wire_bytes` is apportioned proportionally to rows. Partitions are
/// O(1) views sharing the batch's buffers — no rows are copied.
pub fn split(batch: &ColumnBatch, wire_bytes: usize, n: usize) -> Vec<Partition> {
    assert!(n > 0, "partition count must be positive");
    let rows = batch.rows();
    let base = rows / n;
    let extra = rows % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    for j in 0..n {
        let len = base + usize::from(j < extra);
        let part = batch.slice(start, len);
        let wb = if rows == 0 { 0 } else { wire_bytes * len / rows };
        out.push(Partition { index: j, batch: part, wire_bytes: wb });
        start += len;
    }
    debug_assert_eq!(start, rows);
    out
}

/// Chunk-aware split: contiguous row ranges as chunk-list views. Fully
/// covered chunks are shared (O(1) Arc bumps); at most one chunk is
/// sliced at each partition edge. Reassembling the partitions is an
/// O(#chunks) [`ChunkedBatch::concat`] — the round trip copies no rows.
pub fn split_chunked(
    batch: &ChunkedBatch,
    wire_bytes: usize,
    n: usize,
) -> Vec<ChunkedPartition> {
    assert!(n > 0, "partition count must be positive");
    let rows = batch.rows();
    let base = rows / n;
    let extra = rows % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    for j in 0..n {
        let len = base + usize::from(j < extra);
        let part = batch.slice(start, len);
        let wb = if rows == 0 { 0 } else { wire_bytes * len / rows };
        out.push(ChunkedPartition { index: j, batch: part, wire_bytes: wb });
        start += len;
    }
    debug_assert_eq!(start, rows);
    out
}

/// Mean partition wire size in bytes — the `Part_(i,j)` the planner feeds
/// Eqs. 7–9 (partitions are near-uniform, and Spark plans once per batch).
pub fn mean_partition_bytes(total_wire_bytes: usize, n: usize) -> f64 {
    total_wire_bytes as f64 / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, ColumnBatch, Field, Schema};

    fn batch(rows: usize) -> ColumnBatch {
        let schema = Schema::new(vec![Field::f32("x")]);
        ColumnBatch::new(
            schema,
            vec![Column::F32((0..rows).map(|i| i as f32).collect::<Vec<f32>>().into())],
        )
        .unwrap()
    }

    #[test]
    fn covers_all_rows_without_overlap() {
        let b = batch(103);
        let parts = split(&b, 103 * 65, 12);
        assert_eq!(parts.len(), 12);
        let total: usize = parts.iter().map(|p| p.batch.rows()).sum();
        assert_eq!(total, 103);
        // Contiguous coverage: first value of each partition continues on.
        let mut expect = 0f32;
        for p in &parts {
            for &v in p.batch.column("x").unwrap().as_f32().unwrap() {
                assert_eq!(v, expect);
                expect += 1.0;
            }
        }
    }

    #[test]
    fn sizes_differ_by_at_most_one_row() {
        let parts = split(&batch(100), 100, 12);
        let sizes: Vec<usize> = parts.iter().map(|p| p.batch.rows()).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max - min <= 1, "{sizes:?}");
    }

    #[test]
    fn empty_batch_gives_empty_partitions() {
        let parts = split(&batch(0), 0, 4);
        assert_eq!(parts.len(), 4);
        assert!(parts.iter().all(|p| p.batch.rows() == 0 && p.wire_bytes == 0));
    }

    #[test]
    fn fewer_rows_than_partitions() {
        let parts = split(&batch(3), 3 * 65, 12);
        let nonempty = parts.iter().filter(|p| p.batch.rows() > 0).count();
        assert_eq!(nonempty, 3);
    }

    #[test]
    fn mean_partition_size() {
        assert_eq!(mean_partition_bytes(1200, 12), 100.0);
        assert_eq!(mean_partition_bytes(0, 12), 0.0);
    }

    #[test]
    fn chunked_split_matches_contiguous_split() {
        let b = batch(103);
        // Lay the same rows out as three chunks.
        let mut chunked = ChunkedBatch::from_batch(b.slice(0, 40));
        chunked.push(b.slice(40, 30)).unwrap();
        chunked.push(b.slice(70, 33)).unwrap();
        let flat = split(&b, 103 * 65, 12);
        let parts = split_chunked(&chunked, 103 * 65, 12);
        assert_eq!(parts.len(), 12);
        let total: usize = parts.iter().map(|p| p.batch.rows()).sum();
        assert_eq!(total, 103);
        for (cp, fp) in parts.iter().zip(&flat) {
            assert_eq!(cp.wire_bytes, fp.wire_bytes);
            assert_eq!(cp.batch.coalesce().columns, fp.batch.columns);
        }
        // Reassembly is chunk appends and reproduces the input.
        let refs: Vec<&ChunkedBatch> = parts.iter().map(|p| &p.batch).collect();
        let back = ChunkedBatch::concat(&refs).unwrap();
        assert_eq!(back.coalesce().columns, b.columns);
    }
}
