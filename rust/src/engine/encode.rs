//! Encoded column blocks: RLE / dictionary / delta codecs with per-block
//! min/max statistics (the cnosdb-TSM shape: compressed blocks whose
//! stats double as a pruning index).
//!
//! An [`EncodedChunk`] is the compressed form of one [`ColumnBatch`]:
//! every column becomes an [`EncodedBlock`] holding the *smallest honest*
//! encoding of its values — plain, run-length, dictionary (≤ 256
//! distinct), or delta (i32 steps that fit `i8`) — plus the block's
//! min/max. Decoding is exact to the bit: f32 values round-trip by bit
//! pattern (`to_bits`), so NaN payloads and signed zeros survive. That
//! is what lets cold window state live encoded and still satisfy the
//! engine's bit-identity differential harness
//! (`rust/tests/diff_chunked.rs`).
//!
//! Byte accounting mirrors [`ColumnBatch::alloc_bytes`]: one mask byte
//! per row is charged on both sides, so `encoded_bytes() ≤ raw_bytes()`
//! holds unconditionally and the ratio isolates the column-payload win.
//! The device model's coalesce/PCIe terms price these encoded bytes for
//! cold window state (see `devices/model.rs` and ARCHITECTURE.md
//! §Encoded column blocks); the min/max stats feed chunk pruning under
//! fused filter predicates ([`crate::engine::ops::fused`]).

use crate::engine::column::{Buffer, Column, ColumnBatch, Schema, Validity};
use crate::util::hash::FxHashMap;
use std::sync::Arc;

/// Per-column min/max over *all* rows (dead included — a superset bound,
/// so pruning decisions made from it stay conservative). `None` means
/// "no usable bound": an empty column or one containing NaN.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkStats {
    pub per_col: Vec<Option<(f64, f64)>>,
}

impl ChunkStats {
    /// Compute stats directly from a plain batch (the fused kernel uses
    /// this when no encoded block carries them).
    pub fn of(batch: &ColumnBatch) -> ChunkStats {
        ChunkStats {
            per_col: batch.columns.iter().map(column_stats).collect(),
        }
    }
}

/// Min/max bound of one plain column, or `None` when no usable bound
/// exists (empty column, NaN present). The fused aggregate path uses
/// this to price inline pruning one column at a time.
pub fn column_stats(c: &Column) -> Option<(f64, f64)> {
    match c {
        Column::F32(v) => stats_f32(v.as_slice()),
        Column::I32(v) => stats_i32(v.as_slice()),
    }
}

fn stats_f32(vals: &[f32]) -> Option<(f64, f64)> {
    if vals.is_empty() {
        return None;
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in vals {
        if v.is_nan() {
            return None;
        }
        let x = v as f64;
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    Some((lo, hi))
}

fn stats_i32(vals: &[i32]) -> Option<(f64, f64)> {
    if vals.is_empty() {
        return None;
    }
    let mut lo = i32::MAX;
    let mut hi = i32::MIN;
    for &v in vals {
        if v < lo {
            lo = v;
        }
        if v > hi {
            hi = v;
        }
    }
    Some((lo as f64, hi as f64))
}

/// One column's encoded values. `Plain*` keeps the original shared
/// buffer (an O(1) Arc bump — incompressible data costs nothing to
/// "encode"); the other variants own their compact representation.
#[derive(Clone, Debug)]
pub enum EncodedValues {
    PlainF32(Buffer<f32>),
    PlainI32(Buffer<i32>),
    /// Runs of bit-identical values: `(value, run_length)`.
    RleF32(Vec<(f32, u32)>),
    RleI32(Vec<(i32, u32)>),
    /// ≤ 256 distinct values: first-appearance dictionary + u8 codes.
    DictF32 { dict: Vec<f32>, codes: Vec<u8> },
    DictI32 { dict: Vec<i32>, codes: Vec<u8> },
    /// Base value + per-row deltas that fit `i8`.
    DeltaI32 { base: i32, deltas: Vec<i8> },
}

impl EncodedValues {
    /// Bytes this representation occupies (the honest footprint the
    /// cost model prices: 4 per plain/dict/RLE value, 4 per RLE run
    /// length, 1 per dict code / delta).
    pub fn encoded_bytes(&self) -> usize {
        match self {
            EncodedValues::PlainF32(v) => 4 * v.len(),
            EncodedValues::PlainI32(v) => 4 * v.len(),
            EncodedValues::RleF32(runs) => 8 * runs.len(),
            EncodedValues::RleI32(runs) => 8 * runs.len(),
            EncodedValues::DictF32 { dict, codes } => 4 * dict.len() + codes.len(),
            EncodedValues::DictI32 { dict, codes } => 4 * dict.len() + codes.len(),
            EncodedValues::DeltaI32 { deltas, .. } => 4 + deltas.len(),
        }
    }

    /// Decoded row count.
    pub fn rows(&self) -> usize {
        match self {
            EncodedValues::PlainF32(v) => v.len(),
            EncodedValues::PlainI32(v) => v.len(),
            EncodedValues::RleF32(runs) => runs.iter().map(|&(_, n)| n as usize).sum(),
            EncodedValues::RleI32(runs) => runs.iter().map(|&(_, n)| n as usize).sum(),
            EncodedValues::DictF32 { codes, .. } => codes.len(),
            EncodedValues::DictI32 { codes, .. } => codes.len(),
            EncodedValues::DeltaI32 { deltas, .. } => 1 + deltas.len(),
        }
    }

    /// Exact decode (bit-identical to what was encoded).
    pub fn decode(&self) -> Column {
        match self {
            EncodedValues::PlainF32(v) => Column::F32(v.clone()),
            EncodedValues::PlainI32(v) => Column::I32(v.clone()),
            EncodedValues::RleF32(runs) => {
                let mut out = Vec::with_capacity(self.rows());
                for &(v, n) in runs {
                    out.resize(out.len() + n as usize, v);
                }
                Column::F32(out.into())
            }
            EncodedValues::RleI32(runs) => {
                let mut out = Vec::with_capacity(self.rows());
                for &(v, n) in runs {
                    out.resize(out.len() + n as usize, v);
                }
                Column::I32(out.into())
            }
            EncodedValues::DictF32 { dict, codes } => {
                Column::F32(codes.iter().map(|&c| dict[c as usize]).collect::<Vec<_>>().into())
            }
            EncodedValues::DictI32 { dict, codes } => {
                Column::I32(codes.iter().map(|&c| dict[c as usize]).collect::<Vec<_>>().into())
            }
            EncodedValues::DeltaI32 { base, deltas } => {
                let mut out = Vec::with_capacity(1 + deltas.len());
                out.push(*base);
                let mut prev = *base as i64;
                for &d in deltas {
                    prev += d as i64;
                    out.push(prev as i32);
                }
                Column::I32(out.into())
            }
        }
    }
}

/// One encoded column plus its min/max bound.
#[derive(Clone, Debug)]
pub struct EncodedBlock {
    pub values: EncodedValues,
    /// `(min, max)` over all rows; `None` = empty or NaN-bearing.
    pub stats: Option<(f64, f64)>,
}

impl EncodedBlock {
    pub fn encoded_bytes(&self) -> usize {
        self.values.encoded_bytes()
    }
}

/// The encoded form of one [`ColumnBatch`]: per-column blocks + the
/// (unencoded) validity. Validity is 1 byte/row on both sides of the
/// accounting, so it never inflates the encoded/raw ratio.
#[derive(Clone, Debug)]
pub struct EncodedChunk {
    schema: Arc<Schema>,
    blocks: Vec<EncodedBlock>,
    validity: Validity,
}

/// Encode every column of `batch`, picking the smallest honest
/// representation per column (ties go to plain — an O(1) buffer share).
pub fn encode_chunk(batch: &ColumnBatch) -> EncodedChunk {
    let blocks = batch
        .columns
        .iter()
        .map(|c| match c {
            Column::F32(v) => EncodedBlock {
                values: encode_f32(v),
                stats: stats_f32(v.as_slice()),
            },
            Column::I32(v) => EncodedBlock {
                values: encode_i32(v),
                stats: stats_i32(v.as_slice()),
            },
        })
        .collect();
    EncodedChunk {
        schema: Arc::clone(&batch.schema),
        blocks,
        validity: batch.validity.clone(),
    }
}

impl EncodedChunk {
    pub fn schema(&self) -> &Arc<Schema> {
        &self.schema
    }

    pub fn rows(&self) -> usize {
        self.validity.len()
    }

    /// Bytes the encoded representation occupies (blocks + one mask
    /// byte per row, mirroring [`ColumnBatch::alloc_bytes`]).
    pub fn encoded_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.encoded_bytes()).sum::<usize>() + self.rows()
    }

    /// Bytes the decoded form occupies ([`ColumnBatch::alloc_bytes`] of
    /// the decode).
    pub fn raw_bytes(&self) -> usize {
        4 * self.blocks.len() * self.rows() + self.rows()
    }

    /// Per-column min/max (the pruning index).
    pub fn stats(&self) -> ChunkStats {
        ChunkStats { per_col: self.blocks.iter().map(|b| b.stats).collect() }
    }

    /// Exact decode: bit-identical columns, the original validity.
    pub fn decode(&self) -> ColumnBatch {
        ColumnBatch {
            schema: Arc::clone(&self.schema),
            columns: self.blocks.iter().map(|b| b.values.decode()).collect(),
            validity: self.validity.clone(),
        }
    }
}

fn encode_f32(buf: &Buffer<f32>) -> EncodedValues {
    let vals = buf.as_slice();
    let mut best = EncodedValues::PlainF32(buf.clone());
    let mut best_bytes = best.encoded_bytes();
    // RLE over bit patterns (NaN-safe: identical bits run together).
    let mut runs: Vec<(f32, u32)> = Vec::new();
    for &v in vals {
        match runs.last_mut() {
            Some((last, n)) if last.to_bits() == v.to_bits() => *n += 1,
            _ => runs.push((v, 1)),
        }
    }
    if 8 * runs.len() < best_bytes {
        best_bytes = 8 * runs.len();
        best = EncodedValues::RleF32(runs);
    }
    if let Some((dict, codes)) = dict_encode(vals, |v| v.to_bits() as u64) {
        let bytes = 4 * dict.len() + codes.len();
        if bytes < best_bytes {
            best = EncodedValues::DictF32 { dict, codes };
        }
    }
    best
}

fn encode_i32(buf: &Buffer<i32>) -> EncodedValues {
    let vals = buf.as_slice();
    let mut best = EncodedValues::PlainI32(buf.clone());
    let mut best_bytes = best.encoded_bytes();
    let mut runs: Vec<(i32, u32)> = Vec::new();
    for &v in vals {
        match runs.last_mut() {
            Some((last, n)) if *last == v => *n += 1,
            _ => runs.push((v, 1)),
        }
    }
    if 8 * runs.len() < best_bytes {
        best_bytes = 8 * runs.len();
        best = EncodedValues::RleI32(runs);
    }
    if let Some((dict, codes)) = dict_encode(vals, |v| v as u32 as u64) {
        let bytes = 4 * dict.len() + codes.len();
        if bytes < best_bytes {
            best_bytes = bytes;
            best = EncodedValues::DictI32 { dict, codes };
        }
    }
    if let Some((base, deltas)) = delta_encode(vals) {
        if 4 + deltas.len() < best_bytes {
            best = EncodedValues::DeltaI32 { base, deltas };
        }
    }
    best
}

/// First-appearance dictionary with u8 codes; `None` when > 256 distinct
/// values (keying by a stable u64 image so f32 dictionaries compare by
/// bit pattern).
fn dict_encode<T: Copy>(vals: &[T], key: impl Fn(T) -> u64) -> Option<(Vec<T>, Vec<u8>)> {
    let mut slots: FxHashMap<u64, u8> = FxHashMap::default();
    let mut dict: Vec<T> = Vec::new();
    let mut codes: Vec<u8> = Vec::with_capacity(vals.len());
    for &v in vals {
        let k = key(v);
        let code = match slots.get(&k) {
            Some(&c) => c,
            None => {
                if dict.len() == 256 {
                    return None;
                }
                let c = dict.len() as u8;
                slots.insert(k, c);
                dict.push(v);
                c
            }
        };
        codes.push(code);
    }
    Some((dict, codes))
}

/// Base + i8 deltas; `None` when empty or any step overflows `i8`.
fn delta_encode(vals: &[i32]) -> Option<(i32, Vec<i8>)> {
    let (&base, rest) = vals.split_first()?;
    let mut deltas = Vec::with_capacity(rest.len());
    let mut prev = base as i64;
    for &v in rest {
        let d = v as i64 - prev;
        if d < i8::MIN as i64 || d > i8::MAX as i64 {
            return None;
        }
        deltas.push(d as i8);
        prev = v as i64;
    }
    Some((base, deltas))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{DType, Field};
    use crate::util::prop::{prop_assert, Gen, Runner};

    /// Bit image of a column (fingerprint convention: f32 by to_bits).
    fn bits(c: &Column) -> Vec<u8> {
        match c {
            Column::F32(v) => v.iter().flat_map(|x| x.to_bits().to_le_bytes()).collect(),
            Column::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        }
    }

    fn assert_roundtrip(b: &ColumnBatch) {
        let enc = encode_chunk(b);
        let dec = enc.decode();
        assert_eq!(dec.rows(), b.rows());
        assert_eq!(*dec.schema, *b.schema);
        for (x, y) in b.columns.iter().zip(&dec.columns) {
            assert_eq!(bits(x), bits(y), "column bits diverged");
        }
        assert_eq!(dec.validity.to_vec(), b.validity.to_vec());
        assert!(enc.encoded_bytes() <= enc.raw_bytes());
        assert_eq!(enc.raw_bytes(), b.alloc_bytes());
    }

    fn batch(cols: Vec<(Field, Column)>, mask: Option<Vec<u8>>) -> ColumnBatch {
        let (fields, columns): (Vec<_>, Vec<_>) = cols.into_iter().unzip();
        let mut b = ColumnBatch::new(Schema::new(fields), columns).unwrap();
        if let Some(m) = mask {
            b.validity = Validity::from_mask(m);
        }
        b
    }

    #[test]
    fn constant_column_rle_shrinks() {
        let b = batch(
            vec![(Field::f32("v"), Column::F32(vec![7.5; 100].into()))],
            None,
        );
        let enc = encode_chunk(&b);
        assert!(enc.encoded_bytes() < enc.raw_bytes());
        assert_roundtrip(&b);
        // One run of 100: 8 value+length bytes + 100 mask bytes.
        assert_eq!(enc.encoded_bytes(), 8 + 100);
    }

    #[test]
    fn few_distinct_dictionary_shrinks() {
        let vals: Vec<i32> = (0..120).map(|i| [3, 9, 27][i % 3]).collect();
        let b = batch(vec![(Field::i32("k"), Column::I32(vals.into()))], None);
        let enc = encode_chunk(&b);
        assert!(enc.encoded_bytes() < enc.raw_bytes());
        assert_roundtrip(&b);
    }

    #[test]
    fn monotone_i32_delta_shrinks() {
        let vals: Vec<i32> = (0..200).map(|i| 1000 + i).collect();
        let b = batch(vec![(Field::i32("t"), Column::I32(vals.into()))], None);
        let enc = encode_chunk(&b);
        assert!(enc.encoded_bytes() < enc.raw_bytes());
        assert_roundtrip(&b);
    }

    #[test]
    fn incompressible_stays_plain_and_shares_buffer() {
        let vals: Vec<f32> = (0..64).map(|i| (i * 7919) as f32 * 0.37).collect();
        let col = Column::F32(vals.into());
        let b = batch(vec![(Field::f32("v"), col.clone())], None);
        let enc = encode_chunk(&b);
        let dec = enc.decode();
        // Plain fallback shares the original allocation — encoding
        // incompressible data copies nothing.
        assert!(dec.columns[0].shares_memory(&col));
        assert_eq!(enc.encoded_bytes(), enc.raw_bytes());
    }

    #[test]
    fn nan_and_negative_zero_roundtrip_by_bits() {
        let vals = vec![f32::NAN, -0.0, 0.0, f32::from_bits(0x7fc0_dead), f32::NAN];
        let b = batch(vec![(Field::f32("v"), Column::F32(vals.into()))], None);
        let enc = encode_chunk(&b);
        assert!(enc.stats().per_col[0].is_none(), "NaN voids the bound");
        assert_roundtrip(&b);
    }

    #[test]
    fn validity_survives_encoding() {
        let b = batch(
            vec![(Field::f32("v"), Column::F32(vec![1.0, 2.0, 3.0].into()))],
            Some(vec![1, 0, 1]),
        );
        assert_roundtrip(&b);
        let enc = encode_chunk(&b);
        assert_eq!(enc.decode().live_rows(), 2);
    }

    #[test]
    fn empty_batch_roundtrips() {
        let b = batch(vec![(Field::f32("v"), Column::F32(Vec::new().into()))], None);
        let enc = encode_chunk(&b);
        assert_eq!(enc.rows(), 0);
        assert_eq!(enc.encoded_bytes(), 0);
        assert_roundtrip(&b);
    }

    #[test]
    fn stats_match_direct_computation() {
        let b = batch(
            vec![
                (Field::f32("v"), Column::F32(vec![3.0, -1.5, 9.25].into())),
                (Field::i32("k"), Column::I32(vec![5, 5, 2].into())),
            ],
            Some(vec![1, 0, 1]),
        );
        let enc = encode_chunk(&b);
        assert_eq!(enc.stats(), ChunkStats::of(&b));
        // Stats cover dead rows too (conservative superset bound).
        assert_eq!(enc.stats().per_col[0], Some((-1.5, 9.25)));
        assert_eq!(enc.stats().per_col[1], Some((2.0, 5.0)));
    }

    /// Gen-driven random column with a codec-biased shape.
    fn random_column(g: &mut Gen, rows: usize, dtype: DType) -> Column {
        let mode = g.usize_in(0..4);
        match dtype {
            DType::F32 => {
                let vals: Vec<f32> = (0..rows)
                    .map(|i| match mode {
                        0 => g.f64_in(-4.0, 4.0).floor() as f32, // few distinct
                        1 => ((i / 7) as f64 * 1.5) as f32,      // runs
                        _ => g.f64_in(-1000.0, 1000.0) as f32,   // random
                    })
                    .collect();
                Column::F32(vals.into())
            }
            DType::I32 => {
                let mut acc = g.usize_in(0..1000) as i32;
                let vals: Vec<i32> = (0..rows)
                    .map(|i| match mode {
                        0 => (i % 5) as i32 * 11,             // few distinct
                        1 => (i / 9) as i32,                  // runs
                        2 => {
                            acc += g.usize_in(0..100) as i32 - 50; // small deltas
                            acc
                        }
                        _ => g.usize_in(0..1_000_000) as i32, // random
                    })
                    .collect();
                Column::I32(vals.into())
            }
        }
    }

    fn random_batch(g: &mut Gen) -> ColumnBatch {
        let rows = g.usize_in(0..150);
        let ncols = g.usize_in(1..4);
        let cols: Vec<(Field, Column)> = (0..ncols)
            .map(|ci| {
                if g.bool() {
                    (Field::f32(&format!("f{ci}")), random_column(g, rows, DType::F32))
                } else {
                    (Field::i32(&format!("i{ci}")), random_column(g, rows, DType::I32))
                }
            })
            .collect();
        let mask = if g.bool() && rows > 0 {
            Some((0..rows).map(|_| g.bool() as u8).collect())
        } else {
            None
        };
        batch(cols, mask)
    }

    #[test]
    fn prop_roundtrip_is_identity() {
        let mut r = Runner::new(0xe4c0_0001, 150);
        r.run("encode∘decode = id (bits + validity)", |g| {
            let b = random_batch(g);
            let enc = encode_chunk(&b);
            let dec = enc.decode();
            for (ci, (x, y)) in b.columns.iter().zip(&dec.columns).enumerate() {
                if bits(x) != bits(y) {
                    return prop_assert(false, format!("column {ci} bits diverged"));
                }
            }
            prop_assert(
                dec.validity.to_vec() == b.validity.to_vec()
                    && *dec.schema == *b.schema
                    && enc.encoded_bytes() <= enc.raw_bytes(),
                "validity/schema/bytes mismatch",
            )
        });
    }

    #[test]
    fn prop_stats_bound_block_contents() {
        let mut r = Runner::new(0xe4c0_0002, 150);
        r.run("stats bound every value in the block", |g| {
            let b = random_batch(g);
            let enc = encode_chunk(&b);
            for (col, st) in b.columns.iter().zip(&enc.stats().per_col) {
                match st {
                    None => {
                        let nan_or_empty = col.is_empty()
                            || matches!(col, Column::F32(v) if v.iter().any(|x| x.is_nan()));
                        if !nan_or_empty {
                            return prop_assert(false, "bound missing without cause");
                        }
                    }
                    Some((lo, hi)) => {
                        for i in 0..col.len() {
                            let x = col.get_f64(i);
                            if x < *lo || x > *hi {
                                return prop_assert(
                                    false,
                                    format!("value {x} outside [{lo}, {hi}]"),
                                );
                            }
                        }
                    }
                }
            }
            prop_assert(true, "")
        });
    }
}
