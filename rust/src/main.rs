//! `lmstream` — the leader entrypoint / CLI.
//!
//! ```text
//! lmstream run      --workload lr1s --mode lmstream --minutes 5 [--seed N]
//!                   [--cores 12] [--gpus 1] [--trigger 10] [--real]
//!                   [--executors 4] [--checkpoint DIR] [--export DIR]
//! lmstream plan     --workload lr1s --part-kb 64 [--inf-kb 150]
//! lmstream figures  --fig 1|2|5|6|7|8|9|10|table4 [--minutes N]
//! lmstream runtime  [--artifacts DIR]        # PJRT smoke check
//! lmstream version
//! ```

use lmstream::config::{Config, ExecBackend, Mode};
use lmstream::report::figures;
use lmstream::runtime::client::{HostTensor, Runtime};
use lmstream::util::bench::print_table;
use lmstream::util::cli::Args;
use lmstream::workloads;
use std::path::Path;
use std::time::Duration;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn real_main() -> lmstream::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("plan") => cmd_plan(&args),
        Some("figures") => cmd_figures(&args),
        Some("runtime") => cmd_runtime(&args),
        Some("version") => {
            println!("lmstream {}", lmstream::version());
            Ok(())
        }
        _ => {
            println!(
                "lmstream {} — latency-bounded GPU micro-batch stream processing\n\n\
                 subcommands:\n  \
                 run      run a workload (--workload lr1s --mode lmstream --minutes 5)\n  \
                 plan     show a MapDevice plan (--workload lr1s --part-kb 64)\n  \
                 figures  regenerate a paper figure (--fig 6)\n  \
                 runtime  PJRT artifact smoke check\n  \
                 version  print version",
                lmstream::version()
            );
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> lmstream::Result<()> {
    let workload = args.str_or("workload", "lr1s");
    let mode = Mode::parse(&args.str_or("mode", "lmstream"))?;
    let minutes = args.f64_or("minutes", 2.0)?;
    let real = args.flag("real");
    let executors = args.usize_or("executors", 0)?;
    let cfg = Config {
        mode,
        backend: if real { ExecBackend::Real } else { ExecBackend::Simulated },
        num_cores: args.usize_or("cores", 12)?,
        num_gpus: args.usize_or("gpus", 1)?,
        trigger: args.secs_or("trigger", Duration::from_secs(10))?,
        seed: args.u64_or("seed", 0x1a2b3c4d)?,
        artifact_dir: args.str_or("artifacts", "artifacts"),
        cluster: if executors > 0 {
            Some(lmstream::cluster::ClusterSpec::of(executors))
        } else {
            None
        },
        checkpoint_dir: args.str_opt("checkpoint"),
        ..Config::default()
    };
    let export_dir = args.str_opt("export");
    args.finish()?;

    // Session-centric surface: the session owns the runtime, the device
    // model and the online optimizer; the workload is registered once
    // and driven through the shared micro-batch loop.
    let mut session = if real {
        let rt = Runtime::new(Path::new(&cfg.artifact_dir))?;
        lmstream::Session::with_runtime(cfg, rt)?
    } else {
        lmstream::Session::new(cfg)?
    };
    session.register(workloads::by_name(&workload)?)?;
    let mut results = session.run(Duration::from_secs_f64(minutes * 60.0))?;
    let result = results.remove(0);

    println!(
        "{} [{}] — {} micro-batches over {:.1} min",
        result.workload,
        result.mode.name(),
        result.batches.len(),
        minutes
    );
    println!("  avg end-to-end latency : {:>10.3} s", result.avg_latency);
    println!("  avg max latency/batch  : {:>10.3} s", result.avg_max_latency());
    println!(
        "  avg throughput (Eq.4)  : {:>10.1} KB/s",
        result.avg_throughput / 1024.0
    );
    println!("  avg proc time/batch    : {:>10.3} s", result.avg_proc());
    println!(
        "  final inflection point : {:>10.1} KB",
        result.final_inf_pt / 1024.0
    );
    let rows: Vec<Vec<String>> = result
        .phases
        .ratios()
        .iter()
        .map(|(name, pct)| vec![name.to_string(), format!("{pct:.3}%")])
        .collect();
    print_table("phase time ratios (Table IV form)", &["phase", "share"], &rows);
    if let Some(dir) = export_dir {
        lmstream::report::export::write_run(Path::new(&dir), &result)?;
        println!("exported JSON/CSV series to {dir}/");
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> lmstream::Result<()> {
    let workload = args.str_or("workload", "lr1s");
    let part_kb = args.f64_or("part-kb", 64.0)?;
    let inf_kb = args.f64_or("inf-kb", 150.0)?;
    args.finish()?;
    let s = figures::plan_string(&workload, part_kb * 1024.0, inf_kb * 1024.0)?;
    println!("{workload} @ partition {part_kb} KB, inflection {inf_kb} KB:\n  {s}");
    Ok(())
}

fn cmd_figures(args: &Args) -> lmstream::Result<()> {
    let fig = args.str_or("fig", "6");
    let minutes = args.u64_or("minutes", 10)?;
    let seed = args.u64_or("seed", 7)?;
    args.finish()?;
    match fig.as_str() {
        "1" => {
            let r = figures::fig1_series(minutes, seed)?;
            let rows: Vec<Vec<String>> = r
                .batches
                .iter()
                .map(|b| {
                    vec![
                        b.index.to_string(),
                        format!("{:.2}", b.max_latency.as_secs_f64()),
                        b.num_datasets.to_string(),
                    ]
                })
                .collect();
            print_table(
                "Fig.1: static trigger, constant traffic (LR1, CPU)",
                &["batch", "max latency (s)", "datasets"],
                &rows,
            );
        }
        "2" | "5" => {
            let kb = [1, 15, 50, 150, 500, 1500, 5000, 15000, 50000];
            let q = workloads::by_name("spj")?.query;
            let scenarios = figures::spj_scenarios(q.len());
            let mut rows = Vec::new();
            for &k in &kb {
                let bytes = k * 1024;
                let mut row = vec![format!("{k} KB")];
                let cpu_total = figures::spj_cell(bytes, &scenarios[0].1, seed)?.0;
                for (_name, plan) in &scenarios {
                    let (total, transfer) = figures::spj_cell(bytes, plan, seed)?;
                    if fig == "2" {
                        row.push(format!("{:.2}%", transfer / total * 100.0));
                    } else {
                        row.push(format!("{:.2}", total / cpu_total));
                    }
                }
                rows.push(row);
            }
            let header: Vec<&str> = std::iter::once("batch size")
                .chain(scenarios.iter().map(|(n, _)| *n))
                .collect();
            let title = if fig == "2" {
                "Fig.2: PCIe overhead ratio per mapping scenario"
            } else {
                "Fig.5: execution time normalized to all-CPU"
            };
            print_table(title, &header, &rows);
        }
        "6" | "7" => {
            let mut rows = Vec::new();
            for w in workloads::ALL {
                let lm = figures::overall(w, Mode::LmStream, minutes, seed)?;
                let bl = figures::overall(w, Mode::Baseline, minutes, seed)?;
                rows.push(figures::compare_row(&lm, &bl));
            }
            print_table(
                "Figs.6/7: avg latency (s) and throughput (KB/s), constant traffic",
                &["workload", "BL lat", "LM lat", "impr", "BL thpt", "LM thpt", "ratio"],
                &rows,
            );
        }
        "8" | "9" => {
            let w = if fig == "8" { "lr1s" } else { "lr1t" };
            for mode in [Mode::Baseline, Mode::LmStream] {
                let r = figures::timeline(w, mode, minutes, seed)?;
                let rows: Vec<Vec<String>> = r
                    .batches
                    .iter()
                    .map(|b| {
                        vec![
                            format!("{:.1}", b.admitted_at.as_secs_f64()),
                            format!("{:.2}", b.max_latency.as_secs_f64()),
                            format!("{:.1}", b.bytes as f64 / 1024.0),
                        ]
                    })
                    .collect();
                print_table(
                    &format!("Fig.{fig}: {w} timeline [{}]", mode.name()),
                    &["t (s)", "max latency (s)", "batch KB"],
                    &rows,
                );
            }
        }
        "10" => {
            let mut rows = Vec::new();
            for w in workloads::ALL {
                let (dynamic, stat) = figures::dynamic_vs_static(w, minutes, seed)?;
                let impr = (1.0 - dynamic.avg_proc() / stat.avg_proc().max(1e-12)) * 100.0;
                rows.push(vec![
                    w.to_string(),
                    format!("{:.3}", stat.avg_proc()),
                    format!("{:.3}", dynamic.avg_proc()),
                    format!("{impr:.1}%"),
                ]);
            }
            print_table(
                "Fig.10: avg processing phase time (s), static vs dynamic preference",
                &["workload", "static", "dynamic", "impr"],
                &rows,
            );
        }
        "table4" => {
            let mut rows = Vec::new();
            for w in workloads::ALL {
                let r = figures::overhead(w, minutes, seed)?;
                let ratios = r.phases.ratios();
                rows.push(
                    std::iter::once(w.to_string())
                        .chain(ratios.iter().map(|(_, v)| format!("{v:.3}")))
                        .collect(),
                );
            }
            print_table(
                "Table IV: time ratio per step (%)",
                &["workload", "buffering", "construct", "mapdevice", "processing", "optblock"],
                &rows,
            );
        }
        other => {
            return Err(lmstream::Error::Config(format!("unknown figure `{other}`")));
        }
    }
    Ok(())
}

fn cmd_runtime(args: &Args) -> lmstream::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    args.finish()?;
    let rt = Runtime::new(Path::new(&dir))?;
    println!(
        "platform={} artifacts={} buckets={:?}",
        rt.platform(),
        rt.manifest().artifacts.len(),
        rt.manifest().row_buckets
    );
    // Smoke: run the pallas window_aggregate through PJRT.
    let out = rt.execute(
        "window_aggregate",
        4,
        &[
            HostTensor::I32(vec![0, 1, 0, 1]),
            HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0]),
            HostTensor::F32(vec![1.0; 4]),
        ],
    )?;
    let sums = out[0].as_f32()?;
    assert_eq!(sums[0], 4.0);
    assert_eq!(sums[1], 6.0);
    println!("window_aggregate smoke OK: sums[0..2] = {:?}", &sums[..2]);
    Ok(())
}
