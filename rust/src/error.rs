//! Crate-wide error type.

use thiserror::Error;

/// All the ways LMStream operations can fail.
#[derive(Error, Debug)]
pub enum Error {
    /// Underlying XLA / PJRT failure (compile, execute, literal marshal).
    #[error("xla: {0}")]
    Xla(String),

    /// Artifact manifest missing / malformed, or an operator+bucket that
    /// was never AOT-compiled was requested.
    #[error("artifact: {0}")]
    Artifact(String),

    /// Schema violation: unknown column, type mismatch, ragged batch.
    #[error("schema: {0}")]
    Schema(String),

    /// Malformed query DAG (cycle, dangling edge, empty plan).
    #[error("plan: {0}")]
    Plan(String),

    /// Configuration rejected (zero cores, bad bounds, ...).
    #[error("config: {0}")]
    Config(String),

    /// I/O while loading artifacts or writing reports.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// JSON parse failure (manifest).
    #[error("json: {0}")]
    Json(String),

    /// Durability pipeline failure: corrupt WAL record, checkpoint/WAL
    /// position mismatch, sink ledger ahead of the replayable range, or
    /// an unrecoverable condition for the configured recovery mode.
    #[error("durability: {0}")]
    Durability(String),

    /// An executor failed (crash, GPU-device fault, stall) and the
    /// round's retry budget could not recover it — either the budget is
    /// exhausted or no executor survives to re-plan on.
    #[error("executor {executor}: {reason}")]
    Executor {
        /// Physical executor id (index into the configured cluster).
        executor: usize,
        /// What failed and why recovery stopped.
        reason: String,
    },
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
