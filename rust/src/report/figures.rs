//! Figure/table regeneration entry points (shared by `lmstream bench`,
//! the bench targets and EXPERIMENTS.md).
//!
//! Each function runs the relevant experiment and returns printable rows;
//! the per-figure bench binaries add timing and formatting.

use crate::config::{Config, Mode};
use crate::coordinator::driver::{run, RunResult};
use crate::coordinator::planner::SizeEstimator;
use crate::devices::model::{DeviceModel, OpVolume};
use crate::devices::Device;
use crate::engine::chunked::ChunkedBatch;
use crate::engine::window::WindowSpec;
use crate::error::Result;
use crate::query::exec::{self, DevicePlan, ExecEnv};
use crate::query::physical::PhysicalPlan;
use crate::source::traffic::Traffic;
use crate::workloads::{self, synthetic};
use std::time::Duration;

/// Fig. 1 (motivation): per-batch max latency + datasets per batch under
/// the static-trigger model on CPU ("ran it on the Apache Spark cluster",
/// constant traffic).
pub fn fig1_series(minutes: u64, seed: u64) -> Result<RunResult> {
    let w = workloads::by_name("lr1s")?;
    // The motivation experiment predates GPU use: static trigger with all
    // work on CPU (plain Spark). Per §V-A the traffic "fully load[s] the
    // computing capacity"; for the CPU-only setup that regime is a
    // 6-core executor at LR constant traffic (the GPU experiments use the
    // full 12-core + GPU executor).
    let cfg = Config {
        mode: Mode::BaselineCpu,
        num_cores: 6,
        seed,
        ..Config::default()
    };
    run(&w, &cfg, Duration::from_secs(minutes * 60), None)
}

/// One (size, scenario) cell of Figs. 2/5: execute the synthetic SPJ
/// query at `batch_bytes` with the given device plan; returns
/// (total_time_s, transfer_time_s).
pub fn spj_cell(
    batch_bytes: usize,
    plan: &DevicePlan,
    seed: u64,
) -> Result<(f64, f64)> {
    let w = synthetic::spj();
    let model = DeviceModel::default();
    let env = ExecEnv {
        model: &model,
        backend: crate::config::ExecBackend::Simulated,
        num_cores: 12,
        num_gpus: 1,
        runtime: None,
    };
    let mut gen = synthetic::SyntheticGen::new(seed);
    let input = gen.batch_of_bytes(batch_bytes);
    // Build side: window of comparable size (chunked, like the window
    // snapshot the session hands the executor).
    let build = ChunkedBatch::from_batch(gen.batch_of_bytes(batch_bytes));
    let physical = PhysicalPlan::from_devices(&w.query, plan)?;
    let out = exec::execute(&w.query, &physical, input, Some(&build), &env)?;
    Ok((out.proc.as_secs_f64(), out.transfer.as_secs_f64()))
}

/// Figs. 2/5 mapping scenarios.
pub fn spj_scenarios(query_len: usize) -> Vec<(&'static str, DevicePlan)> {
    let all_gpu = DevicePlan::all(Device::Gpu, query_len);
    let all_cpu = DevicePlan::all(Device::Cpu, query_len);
    let mut filter_cpu = all_gpu.clone();
    filter_cpu.per_op[1] = Device::Cpu; // scan, FILTER, project, join
    let mut project_cpu = all_gpu.clone();
    project_cpu.per_op[2] = Device::Cpu;
    vec![
        ("all-CPU", all_cpu),
        ("all-GPU", all_gpu),
        ("filter-on-CPU", filter_cpu),
        ("project-on-CPU", project_cpu),
    ]
}

/// Figs. 6/7: overall latency/throughput per workload, LMStream vs
/// Baseline, constant traffic.
pub fn overall(workload: &str, mode: Mode, minutes: u64, seed: u64) -> Result<RunResult> {
    let w = workloads::by_name(workload)?;
    let cfg = Config { mode, seed, ..Config::default() };
    run(&w, &cfg, Duration::from_secs(minutes * 60), None)
}

/// Figs. 8/9: 20-minute timelines under random traffic.
pub fn timeline(workload: &str, mode: Mode, minutes: u64, seed: u64) -> Result<RunResult> {
    let w = workloads::by_name(workload)?.with_traffic(Traffic::random_default());
    let cfg = Config { mode, seed, ..Config::default() };
    run(&w, &cfg, Duration::from_secs(minutes * 60), None)
}

/// Fig. 10: average processing-phase time, dynamic vs static preference,
/// random traffic with identical totals (same seed → same data).
pub fn dynamic_vs_static(workload: &str, minutes: u64, seed: u64) -> Result<(RunResult, RunResult)> {
    let dynamic = timeline(workload, Mode::LmStream, minutes, seed)?;
    let stat = timeline(workload, Mode::StaticPreference, minutes, seed)?;
    Ok((dynamic, stat))
}

/// Table IV: phase-time ratios for one workload under LMStream.
pub fn overhead(workload: &str, minutes: u64, seed: u64) -> Result<RunResult> {
    overall(workload, Mode::LmStream, minutes, seed)
}

/// Convenience: paper-normalized comparison rows of a two-system run.
pub fn compare_row(lm: &RunResult, bl: &RunResult) -> Vec<String> {
    let lat_impr = if bl.avg_latency > 0.0 {
        (1.0 - lm.avg_latency / bl.avg_latency) * 100.0
    } else {
        0.0
    };
    let thr_ratio = if bl.avg_throughput > 0.0 {
        lm.avg_throughput / bl.avg_throughput
    } else {
        0.0
    };
    vec![
        lm.workload.to_string(),
        format!("{:.2}", bl.avg_latency),
        format!("{:.2}", lm.avg_latency),
        format!("{:.1}%", lat_impr),
        format!("{:.1}", bl.avg_throughput / 1024.0),
        format!("{:.1}", lm.avg_throughput / 1024.0),
        format!("{:.2}x", thr_ratio),
    ]
}

/// PCIe overhead ratio helper for Fig. 2 point checks.
pub fn pcie_ratio(model: &DeviceModel, bytes: f64) -> f64 {
    let transfer = 2.0 * model.transfer_time(bytes).as_secs_f64();
    let compute = model
        .op_time(Device::Gpu, crate::query::dag::OpKind::Project, OpVolume::new(bytes, bytes, 0.0))
        .as_secs_f64();
    transfer / (transfer + compute)
}

/// Planner demonstration used in docs/examples: the device string for a
/// given partition size.
pub fn plan_string(workload: &str, part_bytes: f64, inf_pt: f64) -> Result<String> {
    let w = workloads::by_name(workload)?;
    let est = SizeEstimator::new(w.query.len());
    let plan =
        crate::coordinator::planner::map_device(&w.query, part_bytes, inf_pt, 0.1, &est, 2)?;
    Ok(w.query
        .ops
        .iter()
        .zip(&plan.per_op)
        .map(|(op, p)| format!("{}:{}", op.spec.kind().name(), p.device.name()))
        .collect::<Vec<_>>()
        .join(" → "))
}

/// Shared window spec for ad-hoc experiment assembly.
pub fn default_window() -> WindowSpec {
    WindowSpec::sliding(Duration::from_secs(30), Duration::from_secs(5))
}
