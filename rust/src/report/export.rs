//! Machine-readable experiment export: JSON documents and CSV series for
//! run results, consumed by EXPERIMENTS.md tooling and external plotting.

use crate::coordinator::driver::RunResult;
use crate::error::Result;
use crate::util::json::{arr, num, obj, s, Json};
use std::fmt::Write as _;
use std::path::Path;

/// Full-run JSON document (config echo + aggregates + per-batch series).
pub fn run_to_json(r: &RunResult) -> Json {
    obj(vec![
        ("workload", s(&r.workload)),
        ("mode", s(r.mode.name())),
        ("batches", num(r.batches.len() as f64)),
        ("avg_latency_s", num(r.avg_latency)),
        ("avg_throughput_bps", num(r.avg_throughput)),
        ("avg_proc_s", num(r.avg_proc())),
        ("avg_max_latency_s", num(r.avg_max_latency())),
        ("final_inf_pt_bytes", num(r.final_inf_pt)),
        (
            "phases_pct",
            obj(r.phases
                .ratios()
                .iter()
                .map(|(k, v)| (*k, num(*v)))
                .collect()),
        ),
        (
            "series",
            arr(r.batches
                .iter()
                .map(|b| {
                    obj(vec![
                        ("i", num(b.index as f64)),
                        ("t_s", num(b.admitted_at.as_secs_f64())),
                        ("datasets", num(b.num_datasets as f64)),
                        ("bytes", num(b.bytes as f64)),
                        ("proc_s", num(b.proc.as_secs_f64())),
                        ("max_lat_s", num(b.max_latency.as_secs_f64())),
                        ("inf_pt", num(b.inf_pt)),
                        ("gpu_ops", num(b.gpu_ops as f64)),
                    ])
                })
                .collect()),
        ),
    ])
}

/// Per-batch CSV (one row per micro-batch) for plotting Figs. 1/8/9.
pub fn run_to_csv(r: &RunResult) -> String {
    let mut out = String::from(
        "batch,admitted_s,datasets,bytes,proc_s,max_latency_s,inf_pt_bytes,gpu_ops\n",
    );
    for b in &r.batches {
        let _ = writeln!(
            out,
            "{},{:.3},{},{},{:.6},{:.6},{:.0},{}",
            b.index,
            b.admitted_at.as_secs_f64(),
            b.num_datasets,
            b.bytes,
            b.proc.as_secs_f64(),
            b.max_latency.as_secs_f64(),
            b.inf_pt,
            b.gpu_ops
        );
    }
    out
}

/// Write both forms under `dir` as `<workload>_<mode>.{json,csv}`.
pub fn write_run(dir: &Path, r: &RunResult) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let stem = format!("{}_{}", r.workload.to_lowercase(), r.mode.name().to_lowercase());
    std::fs::write(dir.join(format!("{stem}.json")), run_to_json(r).render())?;
    std::fs::write(dir.join(format!("{stem}.csv")), run_to_csv(r))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, Mode};
    use crate::coordinator::driver;
    use crate::workloads;
    use std::time::Duration;

    fn result() -> RunResult {
        let w = workloads::by_name("cm1t").unwrap();
        let cfg = Config { mode: Mode::LmStream, ..Config::default() };
        driver::run(&w, &cfg, Duration::from_secs(40), None).unwrap()
    }

    #[test]
    fn json_round_trips_and_has_series() {
        let r = result();
        let j = run_to_json(&r);
        let parsed = Json::parse(&j.render()).unwrap();
        assert_eq!(parsed.req("workload").unwrap().as_str(), Some("CM1T"));
        let series = parsed.req("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), r.batches.len());
    }

    #[test]
    fn csv_has_header_plus_rows() {
        let r = result();
        let csv = run_to_csv(&r);
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].starts_with("batch,"));
        assert_eq!(lines.len(), r.batches.len() + 1);
    }

    #[test]
    fn write_run_creates_both_files() {
        let dir = std::env::temp_dir().join(format!("lmstream-export-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let r = result();
        write_run(&dir, &r).unwrap();
        assert!(dir.join("cm1t_lmstream.json").exists());
        assert!(dir.join("cm1t_lmstream.csv").exists());
    }
}
