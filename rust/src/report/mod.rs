//! Experiment regeneration: one entry point per paper figure/table
//! ([`figures`]) plus machine-readable run export ([`export`]).

pub mod export;
pub mod figures;
