//! GPU-path executor: marshals columns into the PJRT artifacts (the
//! AOT-compiled JAX/Pallas operators) and back.
//!
//! Coverage mirrors Spark-Rapids: the data-parallel heavy hitters run on
//! the device (filter, arithmetic projection, windowed aggregation, join
//! probe, sort); plan-level reshapes (column selection, expand, shuffle)
//! stay host-side, as Rapids keeps them in the JVM. Semantics are
//! identical to [`crate::devices::cpu`], asserted by integration tests.
//!
//! Fused chains ([`crate::engine::ops::fused`]) never route here: a
//! Real-backend GPU-device group falls back to staged member execution
//! (the PJRT artifacts are per-op), while the simulated GPU path runs
//! the fused kernel host-side and charges one entering coalesce at the
//! group head — the same once-per-boundary staging [`run_op_chunked`]
//! performs for a staged device kernel below.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::{Column, ColumnBatch, DType, Field, Schema, Validity};
use crate::engine::ops;
use crate::engine::ops::filter::Predicate;
use crate::engine::window::WindowSpec;
use crate::error::{Error, Result};
use crate::query::dag::OpSpec;
use crate::runtime::client::{HostTensor, Runtime};
use crate::util::hash::FxHashMap;

/// Max probe rows per `join_probe` invocation (the artifact's build
/// bucket; larger probes are chunked).
const JOIN_CHUNK: usize = 4096;

/// Execute one operator over the chunked representation through the
/// artifacts. Host-side plan reshapes stay chunk-iterating (via the CPU
/// dispatcher); device kernels marshal contiguous staging buffers, so a
/// chunked input crossing the host→device boundary pays one **explicit
/// coalesce** here (Alg. 2's `Trans` placement; the planner and the
/// simulated cost model charge the same staging via
/// `DeviceModel::coalesce_time`). Kernel outputs come back as a single
/// fresh chunk.
pub fn run_op_chunked(
    rt: &Runtime,
    spec: &OpSpec,
    batch: &ChunkedBatch,
    window: Option<&ChunkedBatch>,
    window_spec: &WindowSpec,
) -> Result<ChunkedBatch> {
    match spec {
        // Host-side plan reshapes (Rapids keeps these in the JVM too):
        // no device boundary, no coalesce.
        OpSpec::Scan
        | OpSpec::ProjectSelect { .. }
        | OpSpec::Expand
        | OpSpec::Shuffle { .. }
        | OpSpec::Union => {
            crate::devices::cpu::run_op_chunked(spec, batch, window, window_spec)
        }
        // Device kernels: stage contiguously once, then run the
        // single-batch artifact path. The window chunk list is staged
        // only for the ops that actually read it (the joins) — other
        // kernels must not pay an O(window) coalesce they'd discard.
        _ => {
            let contiguous = batch.coalesce();
            let staged_window = match spec {
                OpSpec::JoinWithWindow { .. } | OpSpec::JoinWithWindowPruned { .. } => {
                    window.map(|w| w.coalesce())
                }
                _ => None,
            };
            let out = run_op(rt, spec, &contiguous, staged_window.as_ref(), window_spec)?;
            Ok(ChunkedBatch::from_batch(out))
        }
    }
}

fn col_to_f32(c: &Column) -> Vec<f32> {
    match c {
        Column::F32(v) => v.to_vec(),
        Column::I32(v) => v.iter().map(|&x| x as f32).collect(),
    }
}

/// Marshal the validity as the f32 0/1 vector the artifacts expect.
fn valid_to_f32(valid: &Validity) -> Vec<f32> {
    match valid.mask() {
        None => vec![1.0; valid.len()],
        Some(m) => m.iter().map(|&v| (v != 0) as u8 as f32).collect(),
    }
}

/// Execute one operator through the artifacts.
pub fn run_op(
    rt: &Runtime,
    spec: &OpSpec,
    batch: &ColumnBatch,
    window: Option<&ColumnBatch>,
    window_spec: &WindowSpec,
) -> Result<ColumnBatch> {
    match spec {
        // Host-side plan reshapes (Rapids keeps these in the JVM too).
        OpSpec::Scan
        | OpSpec::ProjectSelect { .. }
        | OpSpec::Expand
        | OpSpec::Shuffle { .. }
        | OpSpec::Union => {
            crate::devices::cpu::run_op(spec, batch, window, window_spec)
        }

        OpSpec::Filter { col, pred } => gpu_filter(rt, batch, col, *pred),
        OpSpec::ProjectAffine { a, b, alpha, beta, out } => {
            gpu_project_affine(rt, batch, a, b, *alpha, *beta, out)
        }
        OpSpec::Aggregate { group, aggs, having } => {
            gpu_aggregate(rt, batch, group, aggs, having.as_ref())
        }
        OpSpec::JoinWithWindow { probe_key, build_key } => {
            let build = window.ok_or_else(|| {
                Error::Plan("windowed join requires window state".into())
            })?;
            gpu_join(rt, batch, build, probe_key, build_key)
        }
        OpSpec::JoinWithWindowPruned { probe_key, build_key, probe_cols, build_cols } => {
            // Probe phase on device, pruned materialization host-side.
            let build = window.ok_or_else(|| {
                Error::Plan("windowed join requires window state".into())
            })?;
            let full = gpu_join(rt, batch, build, probe_key, build_key)?;
            let keep: Vec<String> = probe_cols
                .iter()
                .cloned()
                .chain(build_cols.iter().map(|c| format!("r_{c}")))
                .collect();
            let names: Vec<&str> = keep.iter().map(|s| s.as_str()).collect();
            ops::project_select(&full, &names)
        }
        OpSpec::Sort { col, desc } => gpu_sort(rt, batch, col, *desc),
    }
}

fn gpu_filter(rt: &Runtime, batch: &ColumnBatch, col: &str, pred: Predicate) -> Result<ColumnBatch> {
    let rows = batch.rows();
    if rows == 0 {
        return Ok(batch.clone());
    }
    let keys = HostTensor::F32(col_to_f32(batch.column(col)?));
    let valid = HostTensor::F32(valid_to_f32(&batch.validity));
    let out = match pred {
        Predicate::Ge(v) => rt.execute(
            "filter_ge",
            rows,
            &[keys, valid, HostTensor::F32(vec![v as f32])],
        )?,
        Predicate::Lt(v) => rt.execute(
            "filter_lt",
            rows,
            &[keys, valid, HostTensor::F32(vec![v as f32])],
        )?,
        Predicate::Eq(v) => rt.execute(
            "filter_eq",
            rows,
            &[keys, valid, HostTensor::F32(vec![v as f32])],
        )?,
        Predicate::Band(lo, hi) => rt.execute(
            "filter_band",
            rows,
            &[
                keys,
                valid,
                HostTensor::F32(vec![lo as f32]),
                HostTensor::F32(vec![hi as f32]),
            ],
        )?,
    };
    let mut result = batch.clone();
    result.validity =
        Validity::from_mask(out[0].as_f32()?.iter().map(|&v| (v > 0.0) as u8).collect());
    Ok(result)
}

fn gpu_project_affine(
    rt: &Runtime,
    batch: &ColumnBatch,
    a: &str,
    b: &str,
    alpha: f32,
    beta: f32,
    out_name: &str,
) -> Result<ColumnBatch> {
    let rows = batch.rows();
    let mut fields = batch.schema.fields.clone();
    fields.push(Field::f32(out_name));
    let mut columns = batch.columns.clone();
    if rows == 0 {
        columns.push(Column::F32(Vec::new().into()));
    } else {
        let ca = HostTensor::F32(batch.column(a)?.as_f32()?.to_vec());
        let cb = HostTensor::F32(batch.column(b)?.as_f32()?.to_vec());
        let out = rt.execute(
            "project_affine",
            rows,
            &[
                ca,
                cb,
                HostTensor::F32(vec![alpha]),
                HostTensor::F32(vec![beta]),
            ],
        )?;
        columns.push(Column::F32(out[0].as_f32()?.to_vec().into()));
    }
    Ok(ColumnBatch {
        schema: Schema::new(fields),
        columns,
        validity: batch.validity.clone(),
    })
}

/// GPU hash aggregation via the pallas `window_aggregate` kernel: group
/// keys are densified host-side (hash-table build, as Rapids does for its
/// dictionary pass), then per-group sums/counts come from the device.
/// Handles > NUM_GROUPS distinct groups by running the kernel in chunks.
fn gpu_aggregate(
    rt: &Runtime,
    batch: &ColumnBatch,
    group: &[String],
    aggs: &[ops::AggSpec],
    having: Option<&(String, Predicate)>,
) -> Result<ColumnBatch> {
    let num_groups = rt.manifest().num_groups;
    let rows = batch.rows();
    // Densify composite group keys.
    let key_idx: Vec<usize> = group
        .iter()
        .map(|c| batch.schema.index_of(c))
        .collect::<Result<_>>()?;
    let mut slots: FxHashMap<Vec<i64>, i32> = FxHashMap::default();
    let mut order: Vec<Vec<i64>> = Vec::new();
    let mut gids = vec![0i32; rows];
    let live_mask = batch.validity.mask();
    for row in 0..rows {
        if let Some(m) = live_mask {
            if m[row] == 0 {
                continue;
            }
        }
        let key: Vec<i64> = key_idx
            .iter()
            .map(|&ci| match &batch.columns[ci] {
                Column::I32(v) => v[row] as i64,
                Column::F32(v) => v[row].to_bits() as i64,
            })
            .collect();
        let next = order.len() as i32;
        let slot = *slots.entry(key.clone()).or_insert_with(|| {
            order.push(key);
            next
        });
        gids[row] = slot;
    }
    let n_groups = order.len();

    // Per-agg device reduction, chunked over group ranges of NUM_GROUPS.
    let valid_f = valid_to_f32(&batch.validity);
    let mut sums: Vec<Vec<f32>> = vec![vec![0.0; n_groups]; aggs.len()];
    let mut counts: Vec<f32> = vec![0.0; n_groups];
    if rows > 0 {
        for chunk_start in (0..n_groups.max(1)).step_by(num_groups) {
            // Mask rows outside this chunk's group range.
            let mut cgids = vec![0i32; rows];
            let mut cvalid = vec![0.0f32; rows];
            for row in 0..rows {
                let g = gids[row] as usize;
                if valid_f[row] > 0.0 && g >= chunk_start && g < chunk_start + num_groups
                {
                    cgids[row] = (g - chunk_start) as i32;
                    cvalid[row] = valid_f[row];
                }
            }
            for (ai, a) in aggs.iter().enumerate() {
                let values = if a.func == ops::AggFunc::Count {
                    vec![0.0f32; rows]
                } else {
                    col_to_f32(batch.column(&a.value_col)?)
                };
                let out = rt.execute(
                    "window_aggregate",
                    rows,
                    &[
                        HostTensor::I32(cgids.clone()),
                        HostTensor::F32(values),
                        HostTensor::F32(cvalid.clone()),
                    ],
                )?;
                let s = out[0].as_f32()?;
                let c = out[1].as_f32()?;
                for g in 0..num_groups.min(n_groups.saturating_sub(chunk_start)) {
                    sums[ai][chunk_start + g] += s[g];
                    if ai == 0 {
                        counts[chunk_start + g] += c[g];
                    }
                }
            }
            if aggs.is_empty() {
                break;
            }
        }
    }

    // Assemble output (same layout as the native aggregate).
    let mut fields: Vec<Field> = key_idx
        .iter()
        .map(|&ci| batch.schema.fields[ci].clone())
        .collect();
    for a in aggs {
        fields.push(Field::f32(&a.out));
    }
    let mut columns: Vec<Column> = Vec::new();
    for (k, &ci) in key_idx.iter().enumerate() {
        match batch.schema.fields[ci].dtype {
            DType::I32 => columns.push(Column::I32(
                order.iter().map(|key| key[k] as i32).collect::<Vec<i32>>().into(),
            )),
            DType::F32 => columns.push(Column::F32(
                order
                    .iter()
                    .map(|key| f32::from_bits(key[k] as u32))
                    .collect::<Vec<f32>>()
                    .into(),
            )),
        }
    }
    for (ai, a) in aggs.iter().enumerate() {
        let vals: Vec<f32> = (0..n_groups)
            .map(|g| match a.func {
                ops::AggFunc::Sum => sums[ai][g],
                ops::AggFunc::Count => counts[g],
                ops::AggFunc::Avg => sums[ai][g] / counts[g].max(1.0),
            })
            .collect();
        columns.push(Column::F32(vals.into()));
    }
    let mut out = ColumnBatch {
        schema: Schema::new(fields),
        columns,
        validity: Validity::all_live(n_groups),
    };
    if let Some((col, pred)) = having {
        out = ops::filter(&out, col, *pred)?;
    }
    Ok(out)
}

/// GPU join: probe-phase match detection on the device (`join_probe` over
/// build chunks), pair materialization host-side — semantics equal to the
/// native `hash_join`.
fn gpu_join(
    rt: &Runtime,
    probe: &ColumnBatch,
    build: &ColumnBatch,
    probe_key: &str,
    build_key: &str,
) -> Result<ColumnBatch> {
    let pk = col_to_f32(probe.column(probe_key)?);
    let bk = col_to_f32(build.column(build_key)?);
    let p_valid = valid_to_f32(&probe.validity);
    let b_valid = valid_to_f32(&build.validity);

    let mut probe_idx: Vec<usize> = Vec::new();
    let mut build_idx: Vec<usize> = Vec::new();

    // Pre-slice build chunks with their chunk-local hash tables.
    struct Chunk {
        keys: Vec<f32>,
        valid: Vec<f32>,
        table: FxHashMap<u32, Vec<usize>>,
    }
    let mut chunks: Vec<Chunk> = Vec::new();
    for chunk_start in (0..build.rows()).step_by(JOIN_CHUNK) {
        let chunk_end = (chunk_start + JOIN_CHUNK).min(build.rows());
        let keys: Vec<f32> = bk[chunk_start..chunk_end].to_vec();
        let valid: Vec<f32> = b_valid[chunk_start..chunk_end].to_vec();
        let mut table: FxHashMap<u32, Vec<usize>> = FxHashMap::default();
        for (off, &k) in keys.iter().enumerate() {
            if valid[off] > 0.0 {
                table.entry(k.to_bits()).or_default().push(chunk_start + off);
            }
        }
        chunks.push(Chunk { keys, valid, table });
    }

    // Probe-major traversal (matches the native join's output order):
    // device pass per (probe chunk x build chunk) flags matching rows,
    // then pairs are emitted row by row in ascending build order.
    for probe_start in (0..probe.rows()).step_by(JOIN_CHUNK) {
        let probe_end = (probe_start + JOIN_CHUNK).min(probe.rows());
        let rows = probe_end - probe_start;
        let mut found_any = vec![false; rows];
        for chunk in &chunks {
            let out = rt.execute(
                "join_probe",
                rows,
                &[
                    HostTensor::F32(pk[probe_start..probe_end].to_vec()),
                    HostTensor::F32(p_valid[probe_start..probe_end].to_vec()),
                    HostTensor::F32(chunk.keys.clone()),
                    HostTensor::F32(chunk.valid.clone()),
                ],
            )?;
            let found = out[1].as_f32()?;
            for (off, &f) in found.iter().enumerate() {
                if f > 0.0 {
                    found_any[off] = true;
                }
            }
        }
        for (off, &hit) in found_any.iter().enumerate() {
            if !hit {
                continue;
            }
            let row = probe_start + off;
            let key = pk[row].to_bits();
            for chunk in &chunks {
                if let Some(matches) = chunk.table.get(&key) {
                    for &b in matches {
                        probe_idx.push(row);
                        build_idx.push(b);
                    }
                }
            }
        }
    }

    // Materialize (same output layout as native hash_join).
    let mut fields = probe.schema.fields.clone();
    for f in &build.schema.fields {
        fields.push(Field { name: format!("r_{}", f.name), dtype: f.dtype });
    }
    let mut columns: Vec<Column> =
        probe.columns.iter().map(|c| c.take(&probe_idx)).collect();
    for c in &build.columns {
        columns.push(c.take(&build_idx));
    }
    Ok(ColumnBatch {
        schema: Schema::new(fields),
        columns,
        validity: Validity::all_live(probe_idx.len()),
    })
}

fn gpu_sort(rt: &Runtime, batch: &ColumnBatch, col: &str, desc: bool) -> Result<ColumnBatch> {
    let rows = batch.rows();
    if rows == 0 {
        return Ok(batch.clone());
    }
    let mut keys = col_to_f32(batch.column(col)?);
    if desc {
        for k in &mut keys {
            *k = -*k;
        }
    }
    let valid = valid_to_f32(&batch.validity);
    let out = rt.execute(
        "sort_perm",
        rows,
        &[HostTensor::F32(keys), HostTensor::F32(valid)],
    )?;
    let perm: Vec<usize> = out[0]
        .as_i32()?
        .iter()
        .map(|&i| i as usize)
        .filter(|&i| i < rows) // drop padding slots
        .collect();
    if perm.len() != rows {
        return Err(Error::Xla("sort permutation lost rows".into()));
    }
    // Mask hoisted out of the gather (all-live inputs allocate nothing),
    // mirroring the CPU sort path.
    let validity = match batch.validity.mask() {
        None => Validity::all_live(rows),
        Some(mask) => Validity::from_mask(perm.iter().map(|&i| mask[i]).collect()),
    };
    Ok(ColumnBatch {
        schema: batch.schema.clone(),
        columns: batch.columns.iter().map(|c| c.take(&perm)).collect(),
        validity,
    })
}
