//! Execution devices: the calibrated CPU/GPU/PCIe timing model
//! ([`model`]), the native CPU executor ([`cpu`]) and the PJRT-backed GPU
//! executor ([`gpu`]).

pub mod cpu;
pub mod gpu;
pub mod model;

/// The two devices MapDevice chooses between (§III-D).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Device {
    Cpu,
    Gpu,
}

impl Device {
    pub fn name(&self) -> &'static str {
        match self {
            Device::Cpu => "CPU",
            Device::Gpu => "GPU",
        }
    }
}
