//! CPU-path executor: dispatches an [`OpSpec`] to the native operators.
//!
//! [`run_op_chunked`] is the engine path — every operator consumes and
//! produces a [`ChunkedBatch`], iterating the chunk list instead of a
//! coalesced batch (only `sort` coalesces; see `engine::ops::sort`).
//! [`run_op`] remains as the single-batch kernel dispatcher (used per
//! chunk, by the GPU path's host-side fallbacks, and by the CPU↔GPU
//! equivalence tests); the differential harness
//! (`rust/tests/diff_chunked.rs`) pins that the two agree.

use crate::engine::chunked::ChunkedBatch;
use crate::engine::column::ColumnBatch;
use crate::engine::ops;
use crate::engine::ops::fused::FusedChainSpec;
use crate::engine::window::WindowSpec;
use crate::error::{Error, Result};
use crate::query::dag::OpSpec;

/// Execute a fused same-device chain as one typed traversal per chunk
/// (see [`crate::engine::ops::fused`]): predicate sweep, affine compute
/// and group-table feed happen in a single pass, with no intermediate
/// `Validity` mask or column materialization between members. Returns
/// the chain output plus the number of chunks skipped outright because
/// min/max stats proved the chain's filters unsatisfiable. Output is
/// bit-identical to running the members one [`run_op_chunked`] call at
/// a time (the fused differential tests pin this).
pub fn run_fused_chain(
    spec: &FusedChainSpec,
    batch: &ChunkedBatch,
) -> Result<(ChunkedBatch, usize)> {
    ops::fused::run_chunks(batch, spec)
}

/// [`run_fused_chain`] with caller-supplied per-chunk min/max stats
/// (index-aligned with `batch`'s chunk list, `None` = compute inline):
/// window snapshots hand down the bounds already computed when a cold
/// chunk was encoded, so the chain's unsatisfiability pruning skips the
/// per-chunk stats sweep. Output is bit-identical to the stat-less call.
pub fn run_fused_chain_with_stats(
    spec: &FusedChainSpec,
    batch: &ChunkedBatch,
    stats: &[Option<crate::engine::encode::ChunkStats>],
) -> Result<(ChunkedBatch, usize)> {
    ops::fused::run_chunks_with_stats(batch, spec, stats)
}

/// Execute one operator over the chunked representation. `window`
/// supplies the build side for windowed joins (as a chunk list — the
/// window snapshot is never coalesced on this path); `expand_factor`
/// comes from the query's window spec.
pub fn run_op_chunked(
    spec: &OpSpec,
    batch: &ChunkedBatch,
    window: Option<&ChunkedBatch>,
    window_spec: &WindowSpec,
) -> Result<ChunkedBatch> {
    match spec {
        OpSpec::Scan => Ok(batch.clone()),
        OpSpec::Filter { col, pred } => ops::filter_chunks(batch, col, *pred),
        OpSpec::ProjectSelect { keep } => {
            let names: Vec<&str> = keep.iter().map(|s| s.as_str()).collect();
            ops::project_select_chunks(batch, &names)
        }
        OpSpec::ProjectAffine { a, b, alpha, beta, out } => {
            ops::project_affine_chunks(batch, a, b, *alpha, *beta, out)
        }
        OpSpec::Expand => {
            ops::expand_chunks(batch, window_spec.expand_factor() as usize)
        }
        OpSpec::Shuffle { key } => {
            // Single-process exchange: repartition and re-collect
            // (compacts dead rows — the shuffle's observable effect here).
            let parts = ops::shuffle_chunks(batch, key, 1)?;
            Ok(parts.into_iter().next().expect("one shuffle partition"))
        }
        OpSpec::Aggregate { group, aggs, having } => {
            let groups: Vec<&str> = group.iter().map(|s| s.as_str()).collect();
            let hv = having.as_ref().map(|(c, p)| (c.as_str(), *p));
            ops::hash_aggregate_chunks(batch, &groups, aggs, hv)
        }
        OpSpec::JoinWithWindow { probe_key, build_key } => {
            let build = window.ok_or_else(|| {
                Error::Plan("windowed join requires window state".into())
            })?;
            ops::hash_join_chunks(batch, build, probe_key, build_key)
        }
        OpSpec::JoinWithWindowPruned { probe_key, build_key, probe_cols, build_cols } => {
            let build = window.ok_or_else(|| {
                Error::Plan("windowed join requires window state".into())
            })?;
            ops::join::hash_join_chunks_pruned(
                batch, build, probe_key, build_key,
                Some(probe_cols), Some(build_cols),
            )
        }
        OpSpec::Sort { col, desc } => ops::sort_chunks(batch, col, *desc),
        // The executor concatenates a Union's input branches (an
        // O(#chunks) chunk-list append) while assembling its input; the
        // op itself passes through.
        OpSpec::Union => Ok(batch.clone()),
    }
}

/// Execute one operator natively over a single contiguous batch — the
/// per-chunk kernel dispatcher. `window` supplies the build side for
/// windowed joins; `expand_factor` comes from the query's window spec.
pub fn run_op(
    spec: &OpSpec,
    batch: &ColumnBatch,
    window: Option<&ColumnBatch>,
    window_spec: &WindowSpec,
) -> Result<ColumnBatch> {
    match spec {
        OpSpec::Scan => Ok(batch.clone()),
        OpSpec::Filter { col, pred } => ops::filter(batch, col, *pred),
        OpSpec::ProjectSelect { keep } => {
            let names: Vec<&str> = keep.iter().map(|s| s.as_str()).collect();
            ops::project_select(batch, &names)
        }
        OpSpec::ProjectAffine { a, b, alpha, beta, out } => {
            ops::project_affine(batch, a, b, *alpha, *beta, out)
        }
        OpSpec::Expand => ops::expand(batch, window_spec.expand_factor() as usize),
        OpSpec::Shuffle { key } => {
            // Single-process exchange: repartition and re-concatenate
            // (compacts dead rows — the shuffle's observable effect here).
            let parts = ops::shuffle(batch, key, 1)?;
            Ok(parts.into_iter().next().expect("one shuffle partition"))
        }
        OpSpec::Aggregate { group, aggs, having } => {
            let groups: Vec<&str> = group.iter().map(|s| s.as_str()).collect();
            let hv = having.as_ref().map(|(c, p)| (c.as_str(), *p));
            ops::hash_aggregate(batch, &groups, aggs, hv)
        }
        OpSpec::JoinWithWindow { probe_key, build_key } => {
            let build = window.ok_or_else(|| {
                Error::Plan("windowed join requires window state".into())
            })?;
            ops::hash_join(batch, build, probe_key, build_key)
        }
        OpSpec::JoinWithWindowPruned { probe_key, build_key, probe_cols, build_cols } => {
            let build = window.ok_or_else(|| {
                Error::Plan("windowed join requires window state".into())
            })?;
            ops::join::hash_join_pruned(
                batch, build, probe_key, build_key,
                Some(probe_cols), Some(build_cols),
            )
        }
        OpSpec::Sort { col, desc } => ops::sort_by(batch, col, *desc),
        // The executor concatenates a Union's input branches while
        // assembling its input batch; the op itself passes through.
        OpSpec::Union => Ok(batch.clone()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::column::{Column, Field, Schema};
    use crate::engine::ops::filter::Predicate;
    use std::time::Duration;

    fn batch() -> ColumnBatch {
        let schema = Schema::new(vec![Field::i32("k"), Field::f32("v")]);
        ColumnBatch::new(
            schema,
            vec![
                Column::I32(vec![1, 2, 3].into()),
                Column::F32(vec![1.0, 2.0, 3.0].into()),
            ],
        )
        .unwrap()
    }

    fn wspec() -> WindowSpec {
        WindowSpec::tumbling(Duration::from_secs(60))
    }

    #[test]
    fn dispatches_filter() {
        let out = run_op(
            &OpSpec::Filter { col: "v".into(), pred: Predicate::Ge(2.0) },
            &batch(),
            None,
            &wspec(),
        )
        .unwrap();
        assert_eq!(out.live_rows(), 2);
    }

    #[test]
    fn join_without_window_errors() {
        let r = run_op(
            &OpSpec::JoinWithWindow { probe_key: "k".into(), build_key: "k".into() },
            &batch(),
            None,
            &wspec(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn join_with_window_runs() {
        let out = run_op(
            &OpSpec::JoinWithWindow { probe_key: "k".into(), build_key: "k".into() },
            &batch(),
            Some(&batch()),
            &wspec(),
        )
        .unwrap();
        assert_eq!(out.rows(), 3); // self-join on unique keys
    }

    #[test]
    fn shuffle_compacts() {
        let mut b = batch();
        b.validity.set_live(0, false);
        let out = run_op(&OpSpec::Shuffle { key: "k".into() }, &b, None, &wspec()).unwrap();
        assert_eq!(out.rows(), 2);
        assert_eq!(out.live_rows(), out.rows());
    }

    #[test]
    fn chunked_dispatch_matches_single_batch_kernels() {
        let b = batch();
        let mut layout = ChunkedBatch::from_batch(b.slice(0, 1));
        layout.push(b.slice(1, 2)).unwrap();
        for spec in [
            OpSpec::Scan,
            OpSpec::Filter { col: "v".into(), pred: Predicate::Ge(2.0) },
            OpSpec::ProjectSelect { keep: vec!["v".into()] },
            OpSpec::Expand,
            OpSpec::Shuffle { key: "k".into() },
            OpSpec::Sort { col: "v".into(), desc: true },
            OpSpec::Union,
        ] {
            let chunked = run_op_chunked(&spec, &layout, None, &wspec()).unwrap();
            let single = run_op(&spec, &b, None, &wspec()).unwrap();
            assert_eq!(chunked.coalesce(), single, "{spec:?}");
        }
        let join = OpSpec::JoinWithWindow { probe_key: "k".into(), build_key: "k".into() };
        let window = ChunkedBatch::from_batch(b.clone());
        let chunked = run_op_chunked(&join, &layout, Some(&window), &wspec()).unwrap();
        let single = run_op(&join, &b, Some(&b), &wspec()).unwrap();
        assert_eq!(chunked.coalesce(), single);
    }

    #[test]
    fn fused_chain_matches_staged_dispatch() {
        use crate::engine::ops::fused::FusedStep;
        let b = batch();
        let mut layout = ChunkedBatch::from_batch(b.slice(0, 1));
        layout.push(b.slice(1, 2)).unwrap();
        let specs = [
            OpSpec::Scan,
            OpSpec::Filter { col: "v".into(), pred: Predicate::Ge(2.0) },
            OpSpec::ProjectSelect { keep: vec!["v".into()] },
        ];
        let mut staged = layout.clone();
        for spec in &specs {
            staged = run_op_chunked(spec, &staged, None, &wspec()).unwrap();
        }
        let chain = FusedChainSpec {
            steps: vec![
                FusedStep::Scan,
                FusedStep::Filter { col: "v".into(), pred: Predicate::Ge(2.0) },
                FusedStep::Select { keep: vec!["v".into()] },
            ],
            agg: None,
        };
        let (fused, pruned) = run_fused_chain(&chain, &layout).unwrap();
        assert_eq!(pruned, 0);
        assert_eq!(fused.coalesce(), staged.coalesce());
    }
}
