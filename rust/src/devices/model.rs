//! Calibrated device timing model — the simulation's ground-truth
//! "physics", distinct from the planner's cost model (Eqs. 7–9), exactly
//! as a real deployment's hardware differs from its scheduler's estimates.
//!
//! Calibration targets (DESIGN.md §Hardware-Adaptation): one paper
//! executor — 12 Xeon cores + 1 RTX 2080 Ti over PCIe — running the
//! paper's Spark + Spark-Rapids stack, whose *effective* per-byte costs
//! are dominated by the framework (task scheduling, columnar conversion,
//! kernel launch), not raw silicon. The constants reproduce the regime
//! relationships the evaluation depends on:
//!
//! * per-op CPU/GPU crossover within the paper's 15 KB–150 KB band
//!   (Figs. 2/5),
//! * PCIe overhead < 1 % of execution for small data, rising to a
//!   significant share past the inflection region (Fig. 2),
//! * Linear-Road-style constant traffic (≈65 KB/s) "fully loading the
//!   computing capacity" (§V-A): all-CPU processing rate ≈ ingest rate,
//!   all-GPU ≈ 1.2–1.5× CPU, so hybrid CPU+GPU ≈ 2× — the headroom
//!   LMStream's planner converts into its ≤1.74× throughput gain.

use crate::devices::Device;
use crate::query::dag::OpKind;
use std::time::Duration;

/// Work accounting for one operator execution: the byte volumes the model
/// charges for.
#[derive(Clone, Copy, Debug, Default)]
pub struct OpVolume {
    /// Input bytes consumed by the operator.
    pub in_bytes: f64,
    /// Output bytes materialized (captures join/expand amplification).
    pub out_bytes: f64,
    /// Side-input bytes (window state snapshot for windowed ops).
    pub aux_bytes: f64,
}

impl OpVolume {
    pub fn new(in_bytes: f64, out_bytes: f64, aux_bytes: f64) -> OpVolume {
        OpVolume { in_bytes, out_bytes, aux_bytes }
    }

    /// Effective processed bytes: inputs + materialized output + a
    /// discounted pass over the side input (hash build is cheaper than
    /// the probe/materialize side).
    pub fn work_bytes(&self) -> f64 {
        self.in_bytes + self.out_bytes + 0.25 * self.aux_bytes
    }
}

/// Tunable timing parameters.
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    /// Per-partition, per-op CPU task overhead (Spark task dispatch).
    pub cpu_fixed: Duration,
    /// CPU processing cost per effective byte, per core.
    pub cpu_ns_per_byte: f64,
    /// Per-op GPU invocation overhead (kernel launch + Rapids dispatch);
    /// partitions are coalesced per op on the GPU.
    pub gpu_fixed: Duration,
    /// GPU processing cost per effective byte.
    pub gpu_ns_per_byte: f64,
    /// PCIe/host-device transfer latency per transfer.
    pub pcie_lat: Duration,
    /// Transfer cost per byte (includes row↔columnar conversion, the
    /// dominant Spark-Rapids transfer cost).
    pub pcie_ns_per_byte: f64,
    /// Host-side contiguous staging cost per byte: gathering a chunked
    /// batch into the pinned transfer buffer before a host→device copy
    /// (memcpy-rate — cheaper than PCIe + conversion, but not free).
    pub coalesce_ns_per_byte: f64,
    /// Per-micro-batch scheduling overhead (driver, DAG submit, commit).
    pub batch_fixed: Duration,
    /// GPU working-set size beyond which Rapids spills device memory
    /// (the RTX 2080 Ti's 8 GB, scaled to this cost world). The
    /// throughput-oriented baseline's giant buffered batches cross this;
    /// LMStream's bounded batches mostly don't — the "overall performance
    /// degradation caused by buffering" of §V-B.
    pub gpu_mem_bytes: f64,
    /// Host memory pressure threshold for CPU-side spilling.
    pub cpu_mem_bytes: f64,
    /// Extra cost per unit of working set beyond the memory threshold.
    pub spill_slope: f64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        DeviceModel {
            cpu_fixed: Duration::from_millis(15),
            cpu_ns_per_byte: 6_000.0, // 6 µs/B ≈ 166 KB/s effective per core
            gpu_fixed: Duration::from_millis(400),
            gpu_ns_per_byte: 150.0, // 0.15 µs/B ≈ 6.5 MB/s effective
            pcie_lat: Duration::from_micros(50),
            pcie_ns_per_byte: 120.0, // ≈ 8 MB/s incl. columnar conversion
            coalesce_ns_per_byte: 30.0, // ≈ 4x the PCIe rate: pure memcpy
            batch_fixed: Duration::from_millis(300),
            gpu_mem_bytes: 4.5 * 1024.0 * 1024.0,
            cpu_mem_bytes: 48.0 * 1024.0 * 1024.0,
            spill_slope: 2.5,
        }
    }
}

/// Relative work factor per operator kind (the "physics" analog of
/// Table II's base costs).
pub fn op_work_scale(kind: OpKind) -> f64 {
    match kind {
        OpKind::Scan => 1.2,      // CSV parse
        OpKind::Filter => 0.6,
        OpKind::Project => 0.5,
        OpKind::Expand => 0.4,    // replication is copy-bound
        OpKind::Shuffle => 1.0,
        OpKind::Aggregate => 1.5, // hash build + update
        OpKind::Join => 0.8,      // per effective byte; amplification via out_bytes
        OpKind::Sort => 1.3,
        OpKind::Union => 0.3,     // branch merge: pure concat/copy
    }
}

/// Chunk count an operator's output carries for an `in_chunks`-chunk
/// input — the layout "physics" of the chunked kernels in `engine/ops`
/// (mirrored so the planner can price interior CPU→GPU coalesce
/// boundaries by each op's *actual* input layout, not the query input's):
///
/// * per-chunk kernels (scan/filter/project, the join's chunk-by-chunk
///   probe gather, shuffle's per-chunk bucketing) preserve the layout;
/// * `expand` emits one chunk per (window, chunk) pair —
///   `expand_factor × in_chunks`;
/// * `aggregate` (one group table fed chunk-by-chunk) and `sort` (one
///   merged run) materialize a single output chunk;
/// * `Union` is handled by the DAG walk (its input is the *sum* of its
///   branches' chunk lists) and passes that layout through.
pub fn op_output_chunks(kind: OpKind, in_chunks: usize, expand_factor: usize) -> usize {
    match kind {
        OpKind::Aggregate | OpKind::Sort => in_chunks.min(1),
        OpKind::Expand => in_chunks.saturating_mul(expand_factor.max(1)),
        OpKind::Scan
        | OpKind::Filter
        | OpKind::Project
        | OpKind::Shuffle
        | OpKind::Join
        | OpKind::Union => in_chunks,
    }
}

/// GPU efficiency per operator kind (>1 = GPU relatively poor at it).
/// Mirrors the measured preferences of the authors' prior study ([14],
/// Table II): hash aggregation / filtering / shuffling lean CPU; scan and
/// sort lean GPU.
pub fn gpu_relative_cost(kind: OpKind) -> f64 {
    match kind {
        OpKind::Scan => 0.7,
        OpKind::Sort => 0.7,
        OpKind::Project => 0.9,
        OpKind::Join => 0.9,
        OpKind::Expand => 0.9,
        OpKind::Filter => 1.25,
        OpKind::Aggregate => 1.25,
        OpKind::Shuffle => 1.4,
        OpKind::Union => 0.9, // copy-bound merge: mildly GPU-friendly
    }
}

impl DeviceModel {
    /// Time for one operator execution on `device`.
    ///
    /// CPU: `vol` is the per-partition volume (one core runs it).
    /// GPU: `vol` is the coalesced volume of all GPU-mapped partitions
    /// for this op (Rapids batches per-op GPU work).
    pub fn op_time(&self, device: Device, kind: OpKind, vol: OpVolume) -> Duration {
        let work = vol.work_bytes() * op_work_scale(kind) * self.spill_factor(device, vol);
        match device {
            Device::Cpu => {
                self.cpu_fixed + Duration::from_nanos((work * self.cpu_ns_per_byte) as u64)
            }
            Device::Gpu => {
                // The per-op efficiency applies to launch overhead too:
                // CPU-leaning ops (hash agg, shuffle) need more kernel
                // launches / host round-trips in Rapids, not just more
                // cycles per byte.
                let eff = gpu_relative_cost(kind);
                Duration::from_secs_f64(self.gpu_fixed.as_secs_f64() * eff)
                    + Duration::from_nanos((work * self.gpu_ns_per_byte * eff) as u64)
            }
        }
    }

    /// Spill multiplier: 1.0 while the op's working set fits device
    /// memory, growing linearly past it (capped 6x — full out-of-core).
    pub fn spill_factor(&self, device: Device, vol: OpVolume) -> f64 {
        let limit = match device {
            Device::Gpu => self.gpu_mem_bytes,
            Device::Cpu => self.cpu_mem_bytes,
        };
        let working_set = vol.in_bytes + vol.out_bytes + vol.aux_bytes;
        let excess = (working_set / limit - 1.0).max(0.0);
        (1.0 + self.spill_slope * excess).min(6.0)
    }

    /// Host↔device transfer time for `bytes`.
    ///
    /// `bytes` is the *wire* footprint. Window state that rides a
    /// boundary cold-encoded ([`crate::engine::encode`]) is priced at
    /// its encoded byte count: the planner's `QueryCandidate` aux and
    /// the executor's `ExecOpts::aux` carry the same encoded figure, so
    /// the Eq. 9 transfer term never diverges between prediction and
    /// charge.
    pub fn transfer_time(&self, bytes: f64) -> Duration {
        self.pcie_lat + Duration::from_nanos((bytes * self.pcie_ns_per_byte) as u64)
    }

    /// Contiguous staging time for `bytes` entering the device as
    /// `chunks` chunks: the explicit `ChunkedBatch::coalesce` a
    /// GPU-mapped op performs at a host→device boundary (charged
    /// alongside [`transfer_time`] on entering edges; leaving edges are
    /// already contiguous device-side). A single-chunk input coalesces
    /// as an O(1) clone — no per-byte staging copy — so it is free here,
    /// matching the real backend ([`ChunkedBatch::coalesce`]'s
    /// one-chunk short-circuit).
    ///
    /// Like [`transfer_time`], `bytes` is the wire footprint: staging
    /// cold-encoded window chunks gathers the encoded blocks, so
    /// callers price the encoded byte count there too.
    ///
    /// [`transfer_time`]: DeviceModel::transfer_time
    /// [`ChunkedBatch::coalesce`]: crate::engine::chunked::ChunkedBatch::coalesce
    pub fn coalesce_time(&self, bytes: f64, chunks: usize) -> Duration {
        if chunks <= 1 {
            return Duration::ZERO;
        }
        Duration::from_nanos((bytes * self.coalesce_ns_per_byte) as u64)
    }

    /// Data size where CPU and GPU op costs cross for a simple
    /// (in==out==S, no aux) operator of `kind` — the physics' true
    /// inflection point, which the paper's online optimizer is trying to
    /// discover (§III-E).
    pub fn crossover_bytes(&self, kind: OpKind) -> f64 {
        // cpu_fixed + 2S*scale*cpu = gpu_fixed*eff + 2S*scale*gpu*eff + 2 transfers
        let scale = op_work_scale(kind);
        let eff = gpu_relative_cost(kind);
        let fixed_gap = self.gpu_fixed.as_nanos() as f64 * eff
            + (2 * self.pcie_lat).as_nanos() as f64
            - self.cpu_fixed.as_nanos() as f64;
        let per_byte_gap = 2.0 * scale * (self.cpu_ns_per_byte - self.gpu_ns_per_byte * eff)
            - 2.0 * self.pcie_ns_per_byte;
        if per_byte_gap <= 0.0 {
            f64::INFINITY
        } else {
            fixed_gap / per_byte_gap
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KB: f64 = 1024.0;

    fn m() -> DeviceModel {
        DeviceModel::default()
    }

    fn sym(s: f64) -> OpVolume {
        OpVolume::new(s, s, 0.0)
    }

    #[test]
    fn cpu_cheaper_for_small_partitions() {
        for kind in [OpKind::Filter, OpKind::Aggregate, OpKind::Join, OpKind::Scan] {
            let cpu = m().op_time(Device::Cpu, kind, sym(8.0 * KB));
            let gpu = m().op_time(Device::Gpu, kind, sym(8.0 * KB));
            assert!(cpu < gpu, "{kind:?}: cpu {cpu:?} !< gpu {gpu:?}");
        }
    }

    #[test]
    fn gpu_cheaper_for_large_partitions() {
        for kind in [OpKind::Filter, OpKind::Aggregate, OpKind::Join, OpKind::Scan] {
            let cpu = m().op_time(Device::Cpu, kind, sym(2048.0 * KB));
            let gpu = m().op_time(Device::Gpu, kind, sym(2048.0 * KB));
            assert!(gpu < cpu, "{kind:?}: gpu {gpu:?} !< cpu {cpu:?}");
        }
    }

    #[test]
    fn crossover_in_paper_band() {
        // The paper reports per-op preference flips between ~15 KB and
        // ~150 KB (Fig. 5); physics crossovers must land in (or very near)
        // that band for the planner's 150 KB initial inflection to be a
        // sensible-but-improvable starting point.
        for kind in [
            OpKind::Scan,
            OpKind::Filter,
            OpKind::Project,
            OpKind::Aggregate,
            OpKind::Join,
            OpKind::Sort,
            OpKind::Shuffle,
        ] {
            let s = m().crossover_bytes(kind);
            assert!(
                (8.0 * KB..400.0 * KB).contains(&s),
                "{kind:?} crossover {} KB out of band",
                s / KB
            );
        }
    }

    #[test]
    fn gpu_leaning_ops_cross_earlier() {
        // Scan/sort prefer GPU sooner than aggregate/filter/shuffle.
        assert!(m().crossover_bytes(OpKind::Scan) < m().crossover_bytes(OpKind::Aggregate));
        assert!(m().crossover_bytes(OpKind::Sort) < m().crossover_bytes(OpKind::Shuffle));
    }

    #[test]
    fn pcie_overhead_small_below_one_percent() {
        // Fig. 2: transfer/total < 1 % for small data.
        let s = 10.0 * KB;
        let transfer = m().transfer_time(s).as_secs_f64();
        let total = (m().op_time(Device::Gpu, OpKind::Project, sym(s))
            + m().transfer_time(s)
            + m().transfer_time(s))
        .as_secs_f64();
        assert!(transfer / total < 0.01, "ratio {}", transfer / total);
    }

    #[test]
    fn pcie_overhead_significant_for_large() {
        // Fig. 2: the ratio surges well past 1 % for large batches.
        let s = 20.0 * 1024.0 * KB;
        let transfer = 2.0 * m().transfer_time(s).as_secs_f64();
        let total = m().op_time(Device::Gpu, OpKind::Project, sym(s)).as_secs_f64()
            + transfer;
        assert!(transfer / total > 0.05, "ratio {}", transfer / total);
    }

    #[test]
    fn coalesce_staging_cheaper_than_transfer() {
        // Gathering chunks into the staging buffer is memcpy-rate: it
        // must cost strictly less than the PCIe+conversion copy of the
        // same bytes, and scale linearly with no fixed latency.
        let s = 256.0 * KB;
        assert!(m().coalesce_time(s, 4) < m().transfer_time(s));
        assert_eq!(m().coalesce_time(0.0, 4), Duration::ZERO);
        let one = m().coalesce_time(s, 4).as_secs_f64();
        let four = m().coalesce_time(4.0 * s, 4).as_secs_f64();
        assert!((four / one - 4.0).abs() < 0.01, "nonlinear staging cost");
    }

    #[test]
    fn single_chunk_coalesce_is_free() {
        // A one-chunk (or empty) input crosses the boundary via an O(1)
        // clone — no staging copy, no charge.
        let s = 256.0 * KB;
        assert_eq!(m().coalesce_time(s, 1), Duration::ZERO);
        assert_eq!(m().coalesce_time(s, 0), Duration::ZERO);
        assert!(m().coalesce_time(s, 2) > Duration::ZERO);
    }

    #[test]
    fn chunk_propagation_mirrors_kernel_layouts() {
        // Per-chunk kernels preserve; aggregate/sort materialize one
        // chunk; expand multiplies by the window factor.
        for kind in [
            OpKind::Scan,
            OpKind::Filter,
            OpKind::Project,
            OpKind::Shuffle,
            OpKind::Join,
            OpKind::Union,
        ] {
            assert_eq!(op_output_chunks(kind, 4, 6), 4, "{kind:?}");
            assert_eq!(op_output_chunks(kind, 1, 6), 1, "{kind:?}");
        }
        assert_eq!(op_output_chunks(OpKind::Aggregate, 4, 6), 1);
        assert_eq!(op_output_chunks(OpKind::Sort, 4, 6), 1);
        assert_eq!(op_output_chunks(OpKind::Sort, 0, 6), 0);
        assert_eq!(op_output_chunks(OpKind::Expand, 2, 6), 12);
        assert_eq!(op_output_chunks(OpKind::Expand, 2, 0), 2);
    }

    #[test]
    fn work_bytes_discounts_aux() {
        let v = OpVolume::new(100.0, 200.0, 400.0);
        assert_eq!(v.work_bytes(), 100.0 + 200.0 + 100.0);
    }

    #[test]
    fn spill_kicks_in_past_device_memory() {
        let model = m();
        let small = OpVolume::new(1.0 * 1024.0 * KB, 1.0 * 1024.0 * KB, 0.0);
        assert_eq!(model.spill_factor(Device::Gpu, small), 1.0);
        let big = OpVolume::new(16.0 * 1024.0 * KB, 16.0 * 1024.0 * KB, 0.0);
        let f = model.spill_factor(Device::Gpu, big);
        assert!(f > 1.5, "spill factor {f}");
        assert!(model.spill_factor(Device::Cpu, big) < f, "host memory is larger");
        // Cap at full out-of-core.
        let huge = OpVolume::new(1e12, 1e12, 0.0);
        assert_eq!(model.spill_factor(Device::Gpu, huge), 6.0);
    }

    #[test]
    fn capacity_regime_lr_traffic() {
        // LR constant traffic ≈ 30 KB/s (in-memory bytes) with ~30x join
        // amplification (DESIGN.md): the 12-core CPU processing rate over
        // effective bytes must sit near the effective ingest rate (the
        // §V-A "fully loading" condition for the Fig. 1 CPU experiment),
        // while the GPU — at the *baseline's* spilled working sets —
        // saturates too, leaving LMStream's bounded batches (unspilled)
        // the headroom the paper's gains come from.
        let model = m();
        let eff_ingest = 30.0 * KB * 33.0; // bytes/s of effective work
        let cpu_rate = 12.0 * 1e9 / model.cpu_ns_per_byte;
        let rho_cpu = eff_ingest / cpu_rate;
        assert!((0.4..1.3).contains(&rho_cpu), "rho_cpu {rho_cpu}");
        // GPU at baseline working sets (~15 MB vs 4 MB device memory):
        let spill = model.spill_factor(
            Device::Gpu,
            OpVolume::new(0.3e6, 13.0e6, 0.9e6),
        );
        assert!(spill > 2.0, "baseline batches must spill, factor {spill}");
        let gpu_rate_spilled = 1e9 / (model.gpu_ns_per_byte * 0.9 * spill);
        let rho_gpu_baseline = eff_ingest / gpu_rate_spilled;
        // GPU at LMStream working sets (bounded batches, no spill):
        let gpu_rate_clean = 1e9 / (model.gpu_ns_per_byte * 0.9);
        let rho_gpu_lmstream = eff_ingest / gpu_rate_clean;
        assert!(
            rho_gpu_baseline > 1.8 * rho_gpu_lmstream,
            "spill must separate the regimes ({rho_gpu_baseline} vs {rho_gpu_lmstream})"
        );
    }
}
