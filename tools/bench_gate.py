#!/usr/bin/env python3
"""Perf-smoke gate: diff a freshly measured BENCH_hotpath.json against the
committed baseline.

Usage: bench_gate.py BASELINE.json MEASURED.json

Three checks, in decreasing order of machine-independence:

1. ratio gates (always enforced when the baseline declares them):
     - window_snapshot_speedup     >= baseline's `min_window_snapshot_speedup`
     - union_fanin_scaling         <= baseline's `max_union_fanin_scaling`
     - coschedule_makespan_ratio   <= baseline's `max_coschedule_makespan_ratio`
     - fused_vs_staged_ratio       <= baseline's `max_fused_vs_staged_ratio`
     - encoded_window_bytes_ratio  <= baseline's `max_encoded_window_bytes_ratio`
     - shard_scaling_ratio         <= baseline's `max_shard_scaling_ratio`
   These are dimensionless and stable across runners — they encode the
   chunked-path claims (O(#datasets) snapshots; Union assembly cost
   independent of total rows), the co-scheduling claim (the joint
   plan's predicted makespan never exceeds the independent plans
   serialized on the shared GPU), the fusion/encoding claims
   (a fused chain runs no slower than its staged member kernels;
   cold-encoded window state never exceeds its raw footprint), and the
   sharded-runtime claim (the epoch clock pays the max per-source proc
   per round, never more than the serial per-round sum).

2. per-bench mean gate (enforced per entry the baseline carries): each
   measured mean must sit within +/-20% of the baseline mean. Only
   meaningful once the baseline holds a CI-measured point (the committed
   file starts with an empty `results` list; promote a downloaded
   `bench-hotpath` artifact to arm this gate).

3. schema sanity: measured file must be schema_version >= 2 with a
   non-empty results list.

Exit code 0 = pass, 1 = regression, 2 = usage/IO error.
"""

import json
import sys

TOLERANCE = 0.20


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def main():
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    baseline = load(sys.argv[1])
    measured = load(sys.argv[2])
    failures = []

    # 3. schema sanity on the measured point.
    if measured.get("schema_version", 0) < 2:
        failures.append(
            f"measured schema_version {measured.get('schema_version')} < 2"
        )
    if not measured.get("results"):
        failures.append("measured results list is empty — bench did not run")

    # 1. ratio gates.
    min_speedup = baseline.get("min_window_snapshot_speedup")
    if min_speedup is not None:
        got = measured.get("window_snapshot_speedup") or 0.0
        if got < min_speedup:
            failures.append(
                f"window_snapshot_speedup {got:.2f} < required {min_speedup}"
            )
        else:
            print(f"ok: window_snapshot_speedup {got:.2f} >= {min_speedup}")
    max_scaling = baseline.get("max_union_fanin_scaling")
    if max_scaling is not None:
        got = measured.get("union_fanin_scaling")
        if got is None or got <= 0.0:
            failures.append("union_fanin_scaling missing from measured point")
        elif got > max_scaling:
            failures.append(
                f"union_fanin_scaling {got:.2f} > allowed {max_scaling} "
                "(Union assembly is scaling with total rows)"
            )
        else:
            print(f"ok: union_fanin_scaling {got:.2f} <= {max_scaling}")
    max_cosched = baseline.get("max_coschedule_makespan_ratio")
    if max_cosched is not None:
        got = measured.get("coschedule_makespan_ratio")
        if got is None or got <= 0.0:
            failures.append("coschedule_makespan_ratio missing from measured point")
        elif got > max_cosched:
            failures.append(
                f"coschedule_makespan_ratio {got:.3f} > allowed {max_cosched} "
                "(joint plan predicted worse than independent plans)"
            )
        else:
            print(f"ok: coschedule_makespan_ratio {got:.3f} <= {max_cosched}")
    max_fused = baseline.get("max_fused_vs_staged_ratio")
    if max_fused is not None:
        got = measured.get("fused_vs_staged_ratio")
        if got is None or got <= 0.0:
            failures.append("fused_vs_staged_ratio missing from measured point")
        elif got > max_fused:
            failures.append(
                f"fused_vs_staged_ratio {got:.3f} > allowed {max_fused} "
                "(fused chain ran slower than its staged member kernels)"
            )
        else:
            print(f"ok: fused_vs_staged_ratio {got:.3f} <= {max_fused}")
    max_encoded = baseline.get("max_encoded_window_bytes_ratio")
    if max_encoded is not None:
        got = measured.get("encoded_window_bytes_ratio")
        if got is None or got <= 0.0:
            failures.append("encoded_window_bytes_ratio missing from measured point")
        elif got > max_encoded:
            failures.append(
                f"encoded_window_bytes_ratio {got:.3f} > allowed {max_encoded} "
                "(cold-encoded window state exceeds its raw footprint)"
            )
        else:
            print(f"ok: encoded_window_bytes_ratio {got:.3f} <= {max_encoded}")
    max_shard = baseline.get("max_shard_scaling_ratio")
    if max_shard is not None:
        got = measured.get("shard_scaling_ratio")
        if got is None or got <= 0.0:
            failures.append("shard_scaling_ratio missing from measured point")
        elif got > max_shard:
            failures.append(
                f"shard_scaling_ratio {got:.3f} > allowed {max_shard} "
                "(sharded epoch cost exceeds the serial per-round sum)"
            )
        else:
            print(f"ok: shard_scaling_ratio {got:.3f} <= {max_shard}")

    # 2. per-bench +/-20% mean gate against whatever the baseline carries.
    base_means = {
        r["name"]: r["mean_s"]
        for r in baseline.get("results", [])
        if r.get("mean_s")
    }
    got_means = {
        r["name"]: r["mean_s"] for r in measured.get("results", []) if r.get("mean_s")
    }
    for name, base in sorted(base_means.items()):
        got = got_means.get(name)
        if got is None:
            failures.append(f"bench `{name}` missing from measured point")
            continue
        drift = (got - base) / base
        if abs(drift) > TOLERANCE:
            failures.append(
                f"bench `{name}` mean {got:.3e}s drifted {drift:+.0%} "
                f"from baseline {base:.3e}s (gate +/-{TOLERANCE:.0%})"
            )
        else:
            print(f"ok: `{name}` {drift:+.1%} vs baseline")
    if not base_means:
        print(
            "note: baseline carries no per-bench means yet — +/-20% mean gate "
            "idle until a CI-measured artifact is committed as the baseline"
        )

    if failures:
        print("\nbench_gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("bench_gate OK")


if __name__ == "__main__":
    main()
