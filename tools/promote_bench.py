#!/usr/bin/env python3
"""Promote a CI-measured `bench-hotpath` artifact into the committed
perf baseline, arming bench_gate.py's +/-20% per-bench mean drift gate.

Usage:
  promote_bench.py MEASURED.json [--baseline PATH] [--out PATH] [--note TEXT]

  MEASURED.json   a BENCH_hotpath.json downloaded from a green CI
                  perf-smoke run (the `bench-hotpath` artifact)
  --baseline      the committed baseline whose gate fields to preserve
                  (default: rust/BENCH_hotpath.json)
  --out           where to write the promoted baseline
                  (default: overwrite --baseline in place)
  --note          provenance note appended to the output

The promoted file is the measured point (per-bench means + ratio
metrics) with the baseline's machine-independent gate fields
(min_window_snapshot_speedup, max_union_fanin_scaling,
max_coschedule_makespan_ratio, max_fused_vs_staged_ratio,
max_encoded_window_bytes_ratio, max_shard_scaling_ratio) carried
over, and provenance flipped to
"ci-measured". Before writing, the measured point is validated against
those gates — promoting a point that would immediately fail CI is
refused.

Workflow: CI's perf-smoke job runs this after every bench run and
uploads the result as the `bench-baseline-promoted` artifact; download
it from a green run and commit it over rust/BENCH_hotpath.json.

Exit code 0 = promoted, 1 = measured point rejected, 2 = usage/IO error.
"""

import argparse
import json
import sys

GATE_FIELDS = (
    "min_window_snapshot_speedup",
    "max_union_fanin_scaling",
    "max_coschedule_makespan_ratio",
    "max_fused_vs_staged_ratio",
    "max_encoded_window_bytes_ratio",
    "max_shard_scaling_ratio",
)


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"promote_bench: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def validate(measured, gates):
    """The measured point must satisfy the gates it will be committed
    with — otherwise the very next CI run would fail on its own
    baseline."""
    problems = []
    if measured.get("schema_version", 0) < 3:
        problems.append(
            f"schema_version {measured.get('schema_version')} < 3 — stale bench output"
        )
    results = measured.get("results") or []
    if not results:
        problems.append("results list is empty — bench did not run")
    for r in results:
        if not r.get("name") or "mean_s" not in r or r["mean_s"] is None:
            problems.append(f"result entry missing name/mean_s: {r}")
    speedup = measured.get("window_snapshot_speedup") or 0.0
    floor = gates.get("min_window_snapshot_speedup")
    if floor is not None and speedup < floor:
        problems.append(f"window_snapshot_speedup {speedup:.2f} < {floor}")
    scaling = measured.get("union_fanin_scaling")
    cap = gates.get("max_union_fanin_scaling")
    if cap is not None and (scaling is None or scaling <= 0.0 or scaling > cap):
        problems.append(f"union_fanin_scaling {scaling} outside (0, {cap}]")
    ratio = measured.get("coschedule_makespan_ratio")
    cap = gates.get("max_coschedule_makespan_ratio")
    if cap is not None and (ratio is None or ratio <= 0.0 or ratio > cap):
        problems.append(f"coschedule_makespan_ratio {ratio} outside (0, {cap}]")
    ratio = measured.get("fused_vs_staged_ratio")
    cap = gates.get("max_fused_vs_staged_ratio")
    if cap is not None and (ratio is None or ratio <= 0.0 or ratio > cap):
        problems.append(f"fused_vs_staged_ratio {ratio} outside (0, {cap}]")
    ratio = measured.get("encoded_window_bytes_ratio")
    cap = gates.get("max_encoded_window_bytes_ratio")
    if cap is not None and (ratio is None or ratio <= 0.0 or ratio > cap):
        problems.append(f"encoded_window_bytes_ratio {ratio} outside (0, {cap}]")
    ratio = measured.get("shard_scaling_ratio")
    cap = gates.get("max_shard_scaling_ratio")
    if cap is not None and (ratio is None or ratio <= 0.0 or ratio > cap):
        problems.append(f"shard_scaling_ratio {ratio} outside (0, {cap}]")
    return problems


def main():
    ap = argparse.ArgumentParser(
        description="Promote a CI bench artifact into the committed baseline."
    )
    ap.add_argument("measured")
    ap.add_argument("--baseline", default="rust/BENCH_hotpath.json")
    ap.add_argument("--out", default=None)
    ap.add_argument("--note", default=None)
    args = ap.parse_args()

    measured = load(args.measured)
    baseline = load(args.baseline)
    gates = {k: baseline.get(k) for k in GATE_FIELDS if baseline.get(k) is not None}
    if not gates:
        print(
            "promote_bench: baseline declares no gate fields — refusing to "
            "promote an ungated baseline",
            file=sys.stderr,
        )
        sys.exit(1)

    problems = validate(measured, gates)
    if problems:
        print("promote_bench REJECTED the measured point:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        sys.exit(1)

    promoted = dict(measured)
    promoted.update(gates)
    promoted["provenance"] = "ci-measured"
    promoted["note"] = args.note or (
        "CI-measured perf baseline (promoted via tools/promote_bench.py). "
        "Per-bench mean_s entries arm tools/bench_gate.py's +/-20% drift "
        "gate; the min_/max_ ratio gate fields are machine-independent "
        "and carried from the previous baseline. To refresh: download the "
        "bench-baseline-promoted artifact from a green perf-smoke run and "
        "commit it over rust/BENCH_hotpath.json."
    )
    out = args.out or args.baseline
    try:
        with open(out, "w") as f:
            json.dump(promoted, f, separators=(",", ":"))
            f.write("\n")
    except OSError as e:
        print(f"promote_bench: cannot write {out}: {e}", file=sys.stderr)
        sys.exit(2)
    print(
        f"promoted {len(promoted.get('results', []))} bench means into {out} "
        f"(gates: {', '.join(sorted(gates))})"
    )


if __name__ == "__main__":
    main()
